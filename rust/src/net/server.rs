//! The TCP server: acceptor + N io threads over one [`CacheService`],
//! with two event-loop backends behind one seam.
//!
//! Threading model (DESIGN.md §Network front end): one acceptor thread
//! deals accepted sockets round-robin to `io_threads` event-loop
//! threads over channels; each io thread owns its event source and its
//! connections outright, so there is no cross-thread connection state,
//! no locks on the hot path, and a connection's requests stay ordered
//! trivially. Cache-side concurrency comes from [`CacheService`]'s own
//! worker shards — the io threads only decode, fuse, and encode.
//!
//! **epoll (readiness mode)**: level-triggered `epoll_wait`, then
//! `read`/`writev` per ready connection — a connection that still has
//! buffered request bytes after a read-cycle cap keeps its fd
//! readable, so the next wait re-delivers it. Write interest is
//! registered only while a connection has queued response bytes. Cost:
//! ~2N+1 syscalls for N ready connections per tick.
//!
//! **io_uring (completion mode)**: each tick arms batched `recv` /
//! `writev` SQEs for every connection that needs one and harvests
//! whatever completed — one `io_uring_enter` per tick regardless of N.
//! The acceptor runs a multishot `accept` on its own ring (downgrading
//! to one-shot re-arm on kernels that refuse multishot). Connection
//! teardown (error, eviction, sweep) goes through `ASYNC_CANCEL` so an
//! fd is never closed with SQEs still in flight. Both backends drive
//! the same [`Connection`] session core byte-for-byte;
//! [`BackendChoice::Auto`] probes at startup and falls back to epoll.
//!
//! [`CacheService`]: crate::coordinator::CacheService

use super::conn::Connection;
use super::poll::Poller;
use super::uring;
use crate::coordinator::CacheService;
use crate::fault::FaultPlan;
use crate::util::rng::Rng;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sweep idle/deadline-expired connections every this many poll waits
/// (each wait times out after 20ms, so a sweep runs roughly every
/// quarter second — coarse on purpose, timeouts here are seconds-scale
/// overload guards, not precision timers).
const SWEEP_TICKS: u32 = 12;

/// SQ/CQ entries per io-thread ring. 256 SQEs comfortably covers one
/// tick's arming pass (2 SQEs per connection) for the connection
/// counts the harness drives; the arming passes retry on a full SQ, so
/// this is a batching knob, not a correctness bound.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
const URING_IO_ENTRIES: u32 = 256;

/// Entries for the acceptor's ring: one multishot accept (or one-shot
/// re-arms) is all that ever lives here.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
const URING_ACCEPT_ENTRIES: u32 = 64;

/// Which event loop drives the io threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// Readiness mode: raw-syscall epoll ([`super::poll`]). Works on
    /// any Linux; the default for library users.
    Epoll,
    /// Completion mode: raw-syscall io_uring ([`super::uring`]).
    /// [`Server::start`] fails fast with `Unsupported` when the kernel
    /// lacks the required ops.
    Uring,
    /// Probe io_uring at startup, fall back to epoll when the kernel
    /// refuses — never an error. The `kway serve` default.
    Auto,
}

impl BackendChoice {
    /// Parse a `--backend` argument.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "epoll" => Some(Self::Epoll),
            "uring" | "io_uring" => Some(Self::Uring),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    /// The canonical CLI / stats spelling.
    pub fn name(self) -> &'static str {
        match self {
            Self::Epoll => "epoll",
            Self::Uring => "uring",
            Self::Auto => "auto",
        }
    }
}

/// Server tuning knobs. The guard fields all default to *off* (`0` /
/// `None`), so a default-configured server behaves exactly like the
/// pre-guard one; `kway serve` wires them to `--max-conns`,
/// `--max-wq-bytes`, `--idle-timeout` and `--request-deadline`.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Event-loop threads (the acceptor is a separate, mostly-idle
    /// thread). Cache work happens on [`CacheService`]'s own workers,
    /// so a small number of io threads goes a long way.
    ///
    /// [`CacheService`]: crate::coordinator::CacheService
    pub io_threads: usize,
    /// Max simultaneously served connections; `0` = unlimited. Over the
    /// limit the acceptor answers `SERVER_ERROR too many connections`
    /// and closes — an explicit refusal the client can see, instead of
    /// an ever-growing accept backlog. (The protocol is sniffed from a
    /// connection's first byte, which has not arrived at accept time,
    /// so the refusal line is memcached-style on both protocols — a
    /// RESP client sees a malformed reply then EOF, which its framing
    /// treats as a connection error. Documented deviation.)
    pub max_conns: usize,
    /// Per-connection cap on queued unflushed response bytes; `0` =
    /// unlimited. A peer that stops reading while we keep answering is
    /// a *slow client* holding server memory hostage; past the cap the
    /// connection is evicted and counted in `evicted_slow_clients`.
    pub max_wq_bytes: usize,
    /// Close connections with no socket activity for this long.
    pub idle_timeout: Option<Duration>,
    /// Close connections that leave a request *partially* sent for
    /// this long (slowloris-style dribble); complete requests are
    /// answered in the same event cycle and never wait on this.
    pub request_deadline: Option<Duration>,
    /// Fault plan for the io-thread injection points (`io_stall`);
    /// inert unless armed, absent in production configs.
    pub faults: Option<Arc<FaultPlan>>,
    /// Event-loop backend. Defaults to [`BackendChoice::Epoll`] (the
    /// conservative choice for library users and tests); `kway serve`
    /// passes [`BackendChoice::Auto`].
    pub backend: BackendChoice,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            io_threads: 2,
            max_conns: 0,
            max_wq_bytes: 0,
            idle_timeout: None,
            request_deadline: None,
            faults: None,
            backend: BackendChoice::Epoll,
        }
    }
}

/// A running server: join handles plus the shared shutdown flag.
#[derive(Debug)]
pub struct Server {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    accepted: Arc<AtomicU64>,
    backend: BackendChoice,
}

impl Server {
    /// Start serving `listener`'s accepted connections against
    /// `service`. Fails fast (before accepting anything) if the
    /// platform has no event-loop backend — including an explicit
    /// `--backend uring` on a kernel without io_uring — or thread
    /// spawn fails. [`BackendChoice::Auto`] probes io_uring here and
    /// silently falls back to epoll.
    pub fn start(
        listener: TcpListener,
        service: Arc<CacheService>,
        cfg: ServerConfig,
    ) -> io::Result<Server> {
        let backend = match cfg.backend {
            BackendChoice::Epoll => BackendChoice::Epoll,
            BackendChoice::Uring => {
                if !uring::supported() {
                    return Err(io::Error::new(
                        io::ErrorKind::Unsupported,
                        "io_uring backend unavailable on this kernel/platform \
                         (use --backend epoll or auto)",
                    ));
                }
                BackendChoice::Uring
            }
            BackendChoice::Auto => {
                if uring::supported() {
                    BackendChoice::Uring
                } else {
                    BackendChoice::Epoll
                }
            }
        };

        let io_threads = cfg.io_threads.max(1);
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        service.metrics().set_io_backend(backend.name());

        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::with_capacity(io_threads + 1);
        let mut senders = Vec::with_capacity(io_threads);

        match backend {
            BackendChoice::Epoll => {
                // Build every poller up front so an unsupported platform
                // (or fd exhaustion) errors here, not inside a thread.
                let mut pollers = Vec::with_capacity(io_threads);
                for _ in 0..io_threads {
                    pollers.push(Poller::new()?);
                }
                for (i, poller) in pollers.into_iter().enumerate() {
                    let (tx, rx) = mpsc::channel::<Connection>();
                    senders.push(tx);
                    let service = Arc::clone(&service);
                    let shutdown = Arc::clone(&shutdown);
                    let cfg = cfg.clone();
                    let live = Arc::clone(&live);
                    threads.push(std::thread::Builder::new().name(format!("kway-io-{i}")).spawn(
                        move || io_loop(poller, rx, service, shutdown, cfg, live, i as u64),
                    )?);
                }
                let shutdown = Arc::clone(&shutdown);
                let accepted = Arc::clone(&accepted);
                let max_conns = cfg.max_conns;
                threads.push(
                    std::thread::Builder::new().name("kway-accept".into()).spawn(move || {
                        accept_loop(listener, senders, shutdown, accepted, service, max_conns, live)
                    })?,
                );
            }
            BackendChoice::Uring => {
                #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
                {
                    // Rings up front, same fail-fast rationale as pollers.
                    let mut rings = Vec::with_capacity(io_threads);
                    for _ in 0..io_threads {
                        rings.push(uring::Ring::new(URING_IO_ENTRIES)?);
                    }
                    let accept_ring = uring::Ring::new(URING_ACCEPT_ENTRIES)?;
                    for (i, ring) in rings.into_iter().enumerate() {
                        let (tx, rx) = mpsc::channel::<Connection>();
                        senders.push(tx);
                        let service = Arc::clone(&service);
                        let shutdown = Arc::clone(&shutdown);
                        let cfg = cfg.clone();
                        let live = Arc::clone(&live);
                        threads.push(
                            std::thread::Builder::new().name(format!("kway-io-{i}")).spawn(
                                move || {
                                    uring_io_loop(ring, rx, service, shutdown, cfg, live, i as u64)
                                },
                            )?,
                        );
                    }
                    let shutdown = Arc::clone(&shutdown);
                    let accepted = Arc::clone(&accepted);
                    let max_conns = cfg.max_conns;
                    threads.push(
                        std::thread::Builder::new().name("kway-accept".into()).spawn(move || {
                            uring_accept_loop(
                                accept_ring,
                                listener,
                                senders,
                                shutdown,
                                accepted,
                                service,
                                max_conns,
                                live,
                            )
                        })?,
                    );
                }
                #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
                unreachable!("uring resolved on a platform where the probe cannot succeed");
            }
            BackendChoice::Auto => unreachable!("auto was resolved above"),
        }

        Ok(Server { local_addr, shutdown, threads, accepted, backend })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The backend the server resolved to (`Auto` never survives
    /// [`Server::start`]).
    pub fn backend(&self) -> BackendChoice {
        self.backend
    }

    /// Connections accepted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Signal every thread to wind down and join them. Open
    /// connections are dropped (the harness has no draining story —
    /// clients are the load generator and the smoke tests).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Safety net for early-return paths; `stop()` drains `threads`
        // so a normal stop makes this a no-op.
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Accept loop: non-blocking accepts, round-robin dispatch, max-conns
/// refusal. `live` counts dispatched-but-not-yet-closed connections
/// (incremented here, decremented by the owning io thread on every
/// close path).
fn accept_loop(
    listener: TcpListener,
    senders: Vec<mpsc::Sender<Connection>>,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    service: Arc<CacheService>,
    max_conns: usize,
    live: Arc<AtomicUsize>,
) {
    let mut next = 0usize;
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                // Request/response protocols on loopback: Nagle only
                // adds latency. Best-effort.
                let _ = stream.set_nodelay(true);
                accepted.fetch_add(1, Ordering::Relaxed);
                if max_conns > 0 && live.load(Ordering::Relaxed) >= max_conns {
                    // Answer-then-close: a fresh socket's send buffer is
                    // empty, so the nonblocking write virtually always
                    // lands whole; failure just means a silent close.
                    let _ = stream.write_all(b"SERVER_ERROR too many connections\r\n");
                    service.metrics().rejected_conns.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                live.fetch_add(1, Ordering::Relaxed);
                if senders[next % senders.len()].send(Connection::new(stream)).is_err() {
                    return; // io thread gone: shutting down
                }
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// A registered connection slot. The slot index is the poller token.
struct Slot {
    conn: Connection,
    fd: i32,
    want_write: bool,
    /// Last socket event on this connection (idle-timeout clock).
    last_activity: Instant,
    /// When the read buffer first held a partial request with no
    /// complete one to answer (request-deadline clock); cleared as
    /// soon as the buffer empties.
    partial_since: Option<Instant>,
}

/// One io thread: register incoming connections, poll, drive, and —
/// when configured — evict slow clients (write-queue byte cap) and
/// sweep idle / deadline-expired connections off the 20ms wait tick.
fn io_loop(
    poller: Poller,
    rx: mpsc::Receiver<Connection>,
    service: Arc<CacheService>,
    shutdown: Arc<AtomicBool>,
    cfg: ServerConfig,
    live: Arc<AtomicUsize>,
    seed: u64,
) {
    let mut slots: Vec<Option<Slot>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events = Vec::new();
    // Per-thread deterministic rng for the io_stall injection point.
    let mut rng = Rng::new(0xC4A0_5EED ^ seed);
    let mut ticks: u32 = 0;
    let sweeping = cfg.idle_timeout.is_some() || cfg.request_deadline.is_some();
    // Readiness-mode syscall ledger: one per epoll_wait plus whatever
    // each connection's read/writev cycle spent, flushed to the shared
    // metrics once per tick (the counter feeds `syscalls_per_op`).
    let mut syscalls: u64 = 0;

    while !shutdown.load(Ordering::Relaxed) {
        // Adopt newly accepted connections.
        while let Ok(conn) = rx.try_recv() {
            let fd = conn.raw_fd();
            let slot = Slot {
                conn,
                fd,
                want_write: false,
                last_activity: Instant::now(),
                partial_since: None,
            };
            let token = match free.pop() {
                Some(i) => {
                    slots[i] = Some(slot);
                    i
                }
                None => {
                    slots.push(Some(slot));
                    slots.len() - 1
                }
            };
            if poller.add(fd, token as u64, false).is_err() {
                slots[token] = None;
                free.push(token);
                live.fetch_sub(1, Ordering::Relaxed);
            }
        }

        syscalls += 1;
        if poller.wait(&mut events, 20).is_err() {
            // A broken poller cannot recover; drop the thread's
            // connections and exit rather than spin.
            break;
        }

        // Injected scheduling hiccup before this event batch (inert
        // unless a fault plan is armed; see `kway::fault`).
        if let Some(faults) = &cfg.faults {
            if let Some(stall) = faults.io_stall_for(&mut rng) {
                std::thread::sleep(stall);
            }
        }

        for ev in &events {
            let token = ev.token as usize;
            let Some(slot) = slots.get_mut(token).and_then(|s| s.as_mut()) else {
                continue; // raced with removal
            };
            let readable = ev.readable || ev.closed;
            let status = slot.conn.handle(readable, &service);
            syscalls += slot.conn.take_syscalls();
            slot.last_activity = Instant::now();
            slot.partial_since = if slot.conn.has_buffered_request() {
                slot.partial_since.or(Some(slot.last_activity))
            } else {
                None
            };
            let fd = slot.fd;
            let prev_want_write = slot.want_write;
            // A peer that will not read while responses pile up is a
            // slow client; past the byte cap it forfeits the connection
            // (its queued responses are dropped with it).
            let too_slow = cfg.max_wq_bytes > 0 && slot.conn.queued_bytes() > cfg.max_wq_bytes;
            if !status.open || too_slow {
                if status.open {
                    service.metrics().evicted_slow.fetch_add(1, Ordering::Relaxed);
                }
                let _ = poller.delete(fd);
                slots[token] = None;
                free.push(token);
                live.fetch_sub(1, Ordering::Relaxed);
            } else if status.want_write != prev_want_write {
                if poller.modify(fd, token as u64, status.want_write).is_ok() {
                    slot.want_write = status.want_write;
                }
            }
        }

        if syscalls > 0 {
            service.metrics().io_syscalls.fetch_add(syscalls, Ordering::Relaxed);
            syscalls = 0;
        }

        ticks = ticks.wrapping_add(1);
        if sweeping && ticks % SWEEP_TICKS == 0 {
            let now = Instant::now();
            for i in 0..slots.len() {
                let Some(slot) = &slots[i] else { continue };
                let idle = cfg
                    .idle_timeout
                    .is_some_and(|t| now.duration_since(slot.last_activity) > t);
                let stalled = cfg.request_deadline.is_some_and(|t| {
                    slot.partial_since.is_some_and(|since| now.duration_since(since) > t)
                });
                if idle || stalled {
                    let _ = poller.delete(slot.fd);
                    slots[i] = None;
                    free.push(i);
                    live.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }

    // Surrender this thread's live-count share so a restarted server
    // sharing the counter (not a thing today, but cheap insurance)
    // never sees phantom connections.
    for _ in slots.iter().flatten() {
        live.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Max response chunks batched into one completion-mode writev SQE.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
const URING_WRITE_IOVECS: usize = 32;

/// Completion-mode acceptor: one multishot `accept` SQE serves every
/// incoming connection until the kernel retires it (`CQE_F_MORE`
/// absent), with a one-shot re-arm downgrade for kernels that refuse
/// multishot (`EINVAL`). Accepted fds get the same nodelay/nonblocking
/// + max-conns treatment as the readiness-mode acceptor.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
fn uring_accept_loop(
    mut ring: uring::Ring,
    listener: TcpListener,
    senders: Vec<mpsc::Sender<Connection>>,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    service: Arc<CacheService>,
    max_conns: usize,
    live: Arc<AtomicUsize>,
) {
    use std::os::fd::{AsRawFd, FromRawFd};
    const EINVAL: i32 = -22;

    let lfd = listener.as_raw_fd();
    let mut multishot = true;
    let mut armed = false;
    let mut cqes: Vec<uring::Cqe> = Vec::new();
    let mut next = 0usize;

    while !shutdown.load(Ordering::Relaxed) {
        if !armed {
            armed = ring.push_accept(lfd, multishot, 0);
        }
        if ring.submit_and_wait(1, 50).is_err() || ring.harvest(&mut cqes).is_err() {
            break;
        }
        for cqe in cqes.drain(..) {
            if cqe.user_data != 0 {
                continue;
            }
            if !multishot || cqe.flags & uring::CQE_F_MORE == 0 {
                armed = false;
            }
            if cqe.res == EINVAL && multishot {
                // Kernel predates multishot accept: re-arm one-shot.
                multishot = false;
                armed = false;
                continue;
            }
            if cqe.res < 0 {
                continue; // transient accept failure; loop re-arms
            }
            // The CQE result is a fresh connected fd; from here the
            // treatment matches `accept_loop` exactly.
            let mut stream = unsafe { std::net::TcpStream::from_raw_fd(cqe.res) };
            let _ = stream.set_nonblocking(true);
            let _ = stream.set_nodelay(true);
            accepted.fetch_add(1, Ordering::Relaxed);
            if max_conns > 0 && live.load(Ordering::Relaxed) >= max_conns {
                let _ = stream.write_all(b"SERVER_ERROR too many connections\r\n");
                service.metrics().rejected_conns.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            live.fetch_add(1, Ordering::Relaxed);
            if senders[next % senders.len()].send(Connection::new(stream)).is_err() {
                return; // io thread gone: shutting down
            }
            next = next.wrapping_add(1);
        }
    }
}

/// A completion-mode connection slot. The slot index rides in each
/// SQE's `user_data` (`token << 2 | kind`), so a CQE routes straight
/// back here. `recv_buf` and `iovecs` are what the *kernel* reads and
/// writes asynchronously: their heap storage is stable across `Vec`
/// growth of the slot table (only the `Vec` headers move), and each is
/// re-armed/rebuilt only while its operation is not in flight.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
struct USlot {
    conn: Connection,
    fd: i32,
    /// Target of the in-flight `recv` SQE.
    recv_buf: Vec<u8>,
    /// iovec array of the in-flight `writev` SQE, pointing into the
    /// connection's write queue.
    iovecs: Vec<uring::IoVec>,
    recv_inflight: bool,
    write_inflight: bool,
    /// Tear down once in-flight SQEs retire (io error, slow-client
    /// eviction, idle/deadline sweep).
    dead: bool,
    /// Cancels for the in-flight ops were submitted (avoid re-spamming
    /// `ASYNC_CANCEL` every tick while they drain).
    cancel_sent: bool,
    last_activity: Instant,
    partial_since: Option<Instant>,
}

/// One completion-mode io thread. Per tick: adopt new connections, arm
/// a `recv` for every connection without one and a `writev` for every
/// connection with queued output, then **one** `io_uring_enter`
/// submits the whole batch and waits (≤ 20ms) for completions, which
/// are fed back through the same session core as readiness mode. This
/// is the tentpole's syscall claim: N ready connections per tick cost
/// one syscall, not ~2N+1.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn uring_io_loop(
    mut ring: uring::Ring,
    rx: mpsc::Receiver<Connection>,
    service: Arc<CacheService>,
    shutdown: Arc<AtomicBool>,
    cfg: ServerConfig,
    live: Arc<AtomicUsize>,
    seed: u64,
) {
    const RECV_BUF: usize = 16 * 1024;
    const KIND_RECV: u64 = 0;
    const KIND_WRITE: u64 = 1;
    const KIND_CANCEL: u64 = 2;

    let mut slots: Vec<Option<USlot>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut cqes: Vec<uring::Cqe> = Vec::new();
    // Per-thread deterministic rng for the io_stall injection point.
    let mut rng = Rng::new(0xC4A0_5EED ^ seed);
    let mut ticks: u32 = 0;
    let sweeping = cfg.idle_timeout.is_some() || cfg.request_deadline.is_some();

    while !shutdown.load(Ordering::Relaxed) {
        // Adopt newly accepted connections.
        while let Ok(conn) = rx.try_recv() {
            let fd = conn.raw_fd();
            let slot = USlot {
                conn,
                fd,
                recv_buf: vec![0u8; RECV_BUF],
                iovecs: Vec::new(),
                recv_inflight: false,
                write_inflight: false,
                dead: false,
                cancel_sent: false,
                last_activity: Instant::now(),
                partial_since: None,
            };
            match free.pop() {
                Some(i) => slots[i] = Some(slot),
                None => slots.push(Some(slot)),
            }
        }

        // Arming pass. A full SQ leaves `*_inflight` false and the
        // next tick retries — backpressure, not loss.
        for (token, s) in slots.iter_mut().enumerate() {
            let Some(slot) = s else { continue };
            let tok = token as u64;
            if slot.dead || slot.conn.done() {
                // Teardown: never close an fd with SQEs still in
                // flight — cancel them and free the slot once both
                // CQEs have retired.
                if (slot.recv_inflight || slot.write_inflight) && !slot.cancel_sent {
                    let mut sent = true;
                    if slot.recv_inflight {
                        sent &= ring.push_cancel(tok << 2 | KIND_RECV, tok << 2 | KIND_CANCEL);
                    }
                    if slot.write_inflight {
                        sent &= ring.push_cancel(tok << 2 | KIND_WRITE, tok << 2 | KIND_CANCEL);
                    }
                    slot.cancel_sent = sent;
                }
                if !slot.recv_inflight && !slot.write_inflight {
                    *s = None;
                    free.push(token);
                    live.fetch_sub(1, Ordering::Relaxed);
                }
                continue;
            }
            if !slot.recv_inflight && !slot.conn.closing() {
                slot.recv_inflight =
                    ring.push_recv(slot.fd, &mut slot.recv_buf, tok << 2 | KIND_RECV);
            }
            if !slot.write_inflight && slot.conn.has_output() {
                slot.conn.output_iovecs(&mut slot.iovecs, URING_WRITE_IOVECS);
                slot.write_inflight =
                    ring.push_writev(slot.fd, &slot.iovecs, tok << 2 | KIND_WRITE);
            }
        }

        // Injected scheduling hiccup before this tick's submit (inert
        // unless a fault plan is armed; see `kway::fault`).
        if let Some(faults) = &cfg.faults {
            if let Some(stall) = faults.io_stall_for(&mut rng) {
                std::thread::sleep(stall);
            }
        }

        // The tick's one syscall.
        if ring.submit_and_wait(1, 20).is_err() || ring.harvest(&mut cqes).is_err() {
            break;
        }

        for cqe in cqes.drain(..) {
            let token = (cqe.user_data >> 2) as usize;
            let kind = cqe.user_data & 0b11;
            if kind == KIND_CANCEL {
                continue; // the ASYNC_CANCEL op's own completion
            }
            let Some(slot) = slots.get_mut(token).and_then(|s| s.as_mut()) else {
                continue; // slot already freed (both CQEs had retired)
            };
            slot.last_activity = Instant::now();
            match kind {
                KIND_RECV => {
                    slot.recv_inflight = false;
                    if cqe.res > 0 {
                        let n = cqe.res as usize;
                        let _ = slot.conn.ingest(&slot.recv_buf[..n], &service);
                    } else if cqe.res == 0 {
                        slot.conn.note_peer_closed();
                    } else if cqe.res != uring::ECANCELED {
                        slot.dead = true; // io error (reset, …)
                    }
                }
                _ => {
                    slot.write_inflight = false;
                    if cqe.res >= 0 {
                        slot.conn.advance_output(cqe.res as usize);
                    } else if cqe.res != uring::ECANCELED {
                        slot.dead = true;
                    }
                }
            }
            slot.partial_since = if slot.conn.has_buffered_request() {
                slot.partial_since.or(Some(slot.last_activity))
            } else {
                None
            };
            // Slow-client eviction, same rule as readiness mode.
            if !slot.dead && cfg.max_wq_bytes > 0 && slot.conn.queued_bytes() > cfg.max_wq_bytes {
                service.metrics().evicted_slow.fetch_add(1, Ordering::Relaxed);
                slot.dead = true;
            }
        }

        service.metrics().io_syscalls.fetch_add(ring.take_syscalls(), Ordering::Relaxed);

        ticks = ticks.wrapping_add(1);
        if sweeping && ticks % SWEEP_TICKS == 0 {
            let now = Instant::now();
            for slot in slots.iter_mut().flatten() {
                if slot.dead {
                    continue;
                }
                let idle = cfg
                    .idle_timeout
                    .is_some_and(|t| now.duration_since(slot.last_activity) > t);
                let stalled = cfg.request_deadline.is_some_and(|t| {
                    slot.partial_since.is_some_and(|since| now.duration_since(since) > t)
                });
                if idle || stalled {
                    slot.dead = true; // the arming pass cancels + frees
                }
            }
        }
    }

    // The kernel may still be reading `iovecs`/write-queue chunks and
    // writing `recv_buf`s: cancel everything and drain the CQEs before
    // those buffers are freed.
    for (token, slot) in slots.iter().enumerate() {
        let Some(slot) = slot else { continue };
        let tok = token as u64;
        if slot.recv_inflight {
            let _ = ring.push_cancel(tok << 2 | KIND_RECV, tok << 2 | KIND_CANCEL);
        }
        if slot.write_inflight {
            let _ = ring.push_cancel(tok << 2 | KIND_WRITE, tok << 2 | KIND_CANCEL);
        }
    }
    for _ in 0..64 {
        if !slots.iter().flatten().any(|s| s.recv_inflight || s.write_inflight) {
            break;
        }
        if ring.submit_and_wait(1, 20).is_err() || ring.harvest(&mut cqes).is_err() {
            break;
        }
        for cqe in cqes.drain(..) {
            let token = (cqe.user_data >> 2) as usize;
            let Some(slot) = slots.get_mut(token).and_then(|s| s.as_mut()) else { continue };
            match cqe.user_data & 0b11 {
                KIND_RECV => slot.recv_inflight = false,
                KIND_WRITE => slot.write_inflight = false,
                _ => {}
            }
        }
    }
    for slot in slots.drain(..).flatten() {
        live.fetch_sub(1, Ordering::Relaxed);
        if slot.recv_inflight || slot.write_inflight {
            // Safety valve (drain gave up): leak the buffers the kernel
            // may still touch rather than free them. The fd leaks with
            // them; the process is shutting the server down anyway.
            std::mem::forget(slot);
        }
    }
}

#[cfg(test)]
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn start_server() -> (Server, Arc<CacheService>) {
        let cache = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..ServiceConfig::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            Server::start(listener, Arc::clone(&service), ServerConfig::default()).unwrap();
        (server, service)
    }

    #[test]
    fn backend_choice_parses_and_names() {
        assert_eq!(BackendChoice::parse("epoll"), Some(BackendChoice::Epoll));
        assert_eq!(BackendChoice::parse("uring"), Some(BackendChoice::Uring));
        assert_eq!(BackendChoice::parse("io_uring"), Some(BackendChoice::Uring));
        assert_eq!(BackendChoice::parse("auto"), Some(BackendChoice::Auto));
        assert_eq!(BackendChoice::parse("kqueue"), None);
        assert_eq!(BackendChoice::parse(""), None);
        assert_eq!(BackendChoice::Epoll.name(), "epoll");
        assert_eq!(BackendChoice::Uring.name(), "uring");
        assert_eq!(BackendChoice::Auto.name(), "auto");
    }

    #[test]
    fn default_config_stays_on_epoll() {
        // Library users and existing tests get the conservative backend
        // unless they opt in; only the CLI defaults to auto.
        assert_eq!(ServerConfig::default().backend, BackendChoice::Epoll);
    }

    #[test]
    fn serves_memcached_over_loopback() {
        let (server, _service) = start_server();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        stream.write_all(b"set 7 0 0 2\r\n42\r\nget 7\r\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["STORED", "VALUE 7 0 2", "42", "END"]);
        assert!(server.accepted() >= 1);
        server.stop();
    }

    #[test]
    fn serves_resp_and_memcached_concurrently() {
        let (server, _service) = start_server();
        let addr = server.local_addr();

        let mut resp = TcpStream::connect(addr).unwrap();
        resp.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        resp.write_all(b"*3\r\n$3\r\nSET\r\n$2\r\n10\r\n$2\r\n99\r\n").unwrap();
        let mut buf = [0u8; 64];
        let n = std::io::Read::read(&mut resp, &mut buf).unwrap();
        assert_eq!(&buf[..n], b"+OK\r\n");

        let mut mc = TcpStream::connect(addr).unwrap();
        mc.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        mc.write_all(b"get 10\r\n").unwrap();
        let mut reader = BufReader::new(mc.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["VALUE 10 0 2", "99", "END"]);
        server.stop();
    }

    #[test]
    fn stop_is_idempotent_and_drops_open_connections() {
        let (server, _service) = start_server();
        let _open = TcpStream::connect(server.local_addr()).unwrap();
        server.stop();
    }

    fn start_with(cfg: ServerConfig) -> (Server, Arc<CacheService>) {
        let cache = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..ServiceConfig::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = Server::start(listener, Arc::clone(&service), cfg).unwrap();
        (server, service)
    }

    #[test]
    fn over_limit_connections_are_refused_with_an_answer() {
        let (server, service) =
            start_with(ServerConfig { max_conns: 1, ..ServerConfig::default() });
        // Occupy the single slot and prove it is being served.
        let mut first = TcpStream::connect(server.local_addr()).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        first.write_all(b"version\r\n").unwrap();
        let mut reader = BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("VERSION"), "{line:?}");
        // The next connection must be answered then closed.
        let second = TcpStream::connect(server.local_addr()).unwrap();
        second.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(second);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "SERVER_ERROR too many connections");
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "then EOF");
        assert!(service.metrics().rejected_conns.load(Ordering::Relaxed) >= 1);
        drop(first);
        server.stop();
    }

    #[test]
    fn idle_connections_are_reaped() {
        let (server, _service) = start_with(ServerConfig {
            idle_timeout: Some(Duration::from_millis(50)),
            ..ServerConfig::default()
        });
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        c.write_all(b"version\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("VERSION"), "{line:?}");
        // Go quiet: the sweep must close us well within the read timeout.
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut c, &mut buf).unwrap();
        assert_eq!(n, 0, "server must close the idle connection");
        server.stop();
    }

    #[test]
    fn stalled_partial_requests_hit_the_deadline() {
        let (server, _service) = start_with(ServerConfig {
            request_deadline: Some(Duration::from_millis(50)),
            ..ServerConfig::default()
        });
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // A request that never completes (no CRLF) — slowloris dribble.
        c.write_all(b"get 1").unwrap();
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut c, &mut buf).unwrap();
        assert_eq!(n, 0, "server must drop the stalled request");
        server.stop();
    }
}
