//! The TCP server: acceptor + N epoll io threads over one
//! [`CacheService`].
//!
//! Threading model (DESIGN.md §Network front end): one acceptor thread
//! runs a non-blocking `accept` loop and deals accepted sockets
//! round-robin to `io_threads` event-loop threads over channels; each
//! io thread owns a [`Poller`] and its connections outright, so there
//! is no cross-thread connection state, no locks on the hot path, and
//! a connection's requests stay ordered trivially. Cache-side
//! concurrency comes from [`CacheService`]'s own worker shards — the
//! io threads only decode, fuse, and encode.
//!
//! Level-triggered readiness: a connection that still has buffered
//! request bytes after a read-cycle cap keeps its fd readable, so the
//! next `epoll_wait` re-delivers it — no starvation bookkeeping. Write
//! interest is registered only while a connection has queued response
//! bytes (the common case — responses fit the socket buffer — never
//! touches `epoll_ctl`).
//!
//! [`CacheService`]: crate::coordinator::CacheService

use super::conn::Connection;
use super::poll::Poller;
use crate::coordinator::CacheService;
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Event-loop threads (the acceptor is a separate, mostly-idle
    /// thread). Cache work happens on [`CacheService`]'s own workers,
    /// so a small number of io threads goes a long way.
    ///
    /// [`CacheService`]: crate::coordinator::CacheService
    pub io_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { io_threads: 2 }
    }
}

/// A running server: join handles plus the shared shutdown flag.
#[derive(Debug)]
pub struct Server {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    accepted: Arc<AtomicU64>,
}

impl Server {
    /// Start serving `listener`'s accepted connections against
    /// `service`. Fails fast (before accepting anything) if the
    /// platform has no poller backend or thread spawn fails.
    pub fn start(
        listener: TcpListener,
        service: Arc<CacheService>,
        cfg: ServerConfig,
    ) -> io::Result<Server> {
        let io_threads = cfg.io_threads.max(1);
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        // Build every poller up front so an unsupported platform (or
        // fd exhaustion) errors here, not inside a spawned thread.
        let mut pollers = Vec::with_capacity(io_threads);
        for _ in 0..io_threads {
            pollers.push(Poller::new()?);
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::with_capacity(io_threads + 1);
        let mut senders = Vec::with_capacity(io_threads);

        for (i, poller) in pollers.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Connection>();
            senders.push(tx);
            let service = Arc::clone(&service);
            let shutdown = Arc::clone(&shutdown);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("kway-io-{i}"))
                    .spawn(move || io_loop(poller, rx, service, shutdown))?,
            );
        }

        {
            let shutdown = Arc::clone(&shutdown);
            let accepted = Arc::clone(&accepted);
            threads.push(
                std::thread::Builder::new()
                    .name("kway-accept".into())
                    .spawn(move || accept_loop(listener, senders, shutdown, accepted))?,
            );
        }

        Ok(Server { local_addr, shutdown, threads, accepted })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connections accepted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Signal every thread to wind down and join them. Open
    /// connections are dropped (the harness has no draining story —
    /// clients are the load generator and the smoke tests).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Safety net for early-return paths; `stop()` drains `threads`
        // so a normal stop makes this a no-op.
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Accept loop: non-blocking accepts, round-robin dispatch.
fn accept_loop(
    listener: TcpListener,
    senders: Vec<mpsc::Sender<Connection>>,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
) {
    let mut next = 0usize;
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                // Request/response protocols on loopback: Nagle only
                // adds latency. Best-effort.
                let _ = stream.set_nodelay(true);
                accepted.fetch_add(1, Ordering::Relaxed);
                if senders[next % senders.len()].send(Connection::new(stream)).is_err() {
                    return; // io thread gone: shutting down
                }
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// A registered connection slot. The slot index is the poller token.
struct Slot {
    conn: Connection,
    fd: i32,
    want_write: bool,
}

/// One io thread: register incoming connections, poll, drive.
fn io_loop(
    poller: Poller,
    rx: mpsc::Receiver<Connection>,
    service: Arc<CacheService>,
    shutdown: Arc<AtomicBool>,
) {
    let mut slots: Vec<Option<Slot>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events = Vec::new();

    while !shutdown.load(Ordering::Relaxed) {
        // Adopt newly accepted connections.
        while let Ok(conn) = rx.try_recv() {
            let fd = conn.raw_fd();
            let token = match free.pop() {
                Some(i) => {
                    slots[i] = Some(Slot { conn, fd, want_write: false });
                    i
                }
                None => {
                    slots.push(Some(Slot { conn, fd, want_write: false }));
                    slots.len() - 1
                }
            };
            if poller.add(fd, token as u64, false).is_err() {
                slots[token] = None;
                free.push(token);
            }
        }

        if poller.wait(&mut events, 20).is_err() {
            // A broken poller cannot recover; drop the thread's
            // connections and exit rather than spin.
            return;
        }

        for ev in &events {
            let token = ev.token as usize;
            let Some(slot) = slots.get_mut(token).and_then(|s| s.as_mut()) else {
                continue; // raced with removal
            };
            let readable = ev.readable || ev.closed;
            let status = slot.conn.handle(readable, &service);
            let fd = slot.fd;
            let prev_want_write = slot.want_write;
            if !status.open {
                let _ = poller.delete(fd);
                slots[token] = None;
                free.push(token);
            } else if status.want_write != prev_want_write {
                if poller.modify(fd, token as u64, status.want_write).is_ok() {
                    slot.want_write = status.want_write;
                }
            }
        }
    }
}

#[cfg(test)]
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn start_server() -> (Server, Arc<CacheService>) {
        let cache = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..ServiceConfig::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            Server::start(listener, Arc::clone(&service), ServerConfig::default()).unwrap();
        (server, service)
    }

    #[test]
    fn serves_memcached_over_loopback() {
        let (server, _service) = start_server();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        stream.write_all(b"set 7 0 0 2\r\n42\r\nget 7\r\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["STORED", "VALUE 7 0 2", "42", "END"]);
        assert!(server.accepted() >= 1);
        server.stop();
    }

    #[test]
    fn serves_resp_and_memcached_concurrently() {
        let (server, _service) = start_server();
        let addr = server.local_addr();

        let mut resp = TcpStream::connect(addr).unwrap();
        resp.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        resp.write_all(b"*3\r\n$3\r\nSET\r\n$2\r\n10\r\n$2\r\n99\r\n").unwrap();
        let mut buf = [0u8; 64];
        let n = std::io::Read::read(&mut resp, &mut buf).unwrap();
        assert_eq!(&buf[..n], b"+OK\r\n");

        let mut mc = TcpStream::connect(addr).unwrap();
        mc.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        mc.write_all(b"get 10\r\n").unwrap();
        let mut reader = BufReader::new(mc.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["VALUE 10 0 2", "99", "END"]);
        server.stop();
    }

    #[test]
    fn stop_is_idempotent_and_drops_open_connections() {
        let (server, _service) = start_server();
        let _open = TcpStream::connect(server.local_addr()).unwrap();
        server.stop();
    }
}
