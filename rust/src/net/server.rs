//! The TCP server: acceptor + N epoll io threads over one
//! [`CacheService`].
//!
//! Threading model (DESIGN.md §Network front end): one acceptor thread
//! runs a non-blocking `accept` loop and deals accepted sockets
//! round-robin to `io_threads` event-loop threads over channels; each
//! io thread owns a [`Poller`] and its connections outright, so there
//! is no cross-thread connection state, no locks on the hot path, and
//! a connection's requests stay ordered trivially. Cache-side
//! concurrency comes from [`CacheService`]'s own worker shards — the
//! io threads only decode, fuse, and encode.
//!
//! Level-triggered readiness: a connection that still has buffered
//! request bytes after a read-cycle cap keeps its fd readable, so the
//! next `epoll_wait` re-delivers it — no starvation bookkeeping. Write
//! interest is registered only while a connection has queued response
//! bytes (the common case — responses fit the socket buffer — never
//! touches `epoll_ctl`).
//!
//! [`CacheService`]: crate::coordinator::CacheService

use super::conn::Connection;
use super::poll::Poller;
use crate::coordinator::CacheService;
use crate::fault::FaultPlan;
use crate::util::rng::Rng;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Sweep idle/deadline-expired connections every this many poll waits
/// (each wait times out after 20ms, so a sweep runs roughly every
/// quarter second — coarse on purpose, timeouts here are seconds-scale
/// overload guards, not precision timers).
const SWEEP_TICKS: u32 = 12;

/// Server tuning knobs. The guard fields all default to *off* (`0` /
/// `None`), so a default-configured server behaves exactly like the
/// pre-guard one; `kway serve` wires them to `--max-conns`,
/// `--max-wq-bytes`, `--idle-timeout` and `--request-deadline`.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Event-loop threads (the acceptor is a separate, mostly-idle
    /// thread). Cache work happens on [`CacheService`]'s own workers,
    /// so a small number of io threads goes a long way.
    ///
    /// [`CacheService`]: crate::coordinator::CacheService
    pub io_threads: usize,
    /// Max simultaneously served connections; `0` = unlimited. Over the
    /// limit the acceptor answers `SERVER_ERROR too many connections`
    /// and closes — an explicit refusal the client can see, instead of
    /// an ever-growing accept backlog. (The protocol is sniffed from a
    /// connection's first byte, which has not arrived at accept time,
    /// so the refusal line is memcached-style on both protocols — a
    /// RESP client sees a malformed reply then EOF, which its framing
    /// treats as a connection error. Documented deviation.)
    pub max_conns: usize,
    /// Per-connection cap on queued unflushed response bytes; `0` =
    /// unlimited. A peer that stops reading while we keep answering is
    /// a *slow client* holding server memory hostage; past the cap the
    /// connection is evicted and counted in `evicted_slow_clients`.
    pub max_wq_bytes: usize,
    /// Close connections with no socket activity for this long.
    pub idle_timeout: Option<Duration>,
    /// Close connections that leave a request *partially* sent for
    /// this long (slowloris-style dribble); complete requests are
    /// answered in the same event cycle and never wait on this.
    pub request_deadline: Option<Duration>,
    /// Fault plan for the io-thread injection points (`io_stall`);
    /// inert unless armed, absent in production configs.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            io_threads: 2,
            max_conns: 0,
            max_wq_bytes: 0,
            idle_timeout: None,
            request_deadline: None,
            faults: None,
        }
    }
}

/// A running server: join handles plus the shared shutdown flag.
#[derive(Debug)]
pub struct Server {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    accepted: Arc<AtomicU64>,
}

impl Server {
    /// Start serving `listener`'s accepted connections against
    /// `service`. Fails fast (before accepting anything) if the
    /// platform has no poller backend or thread spawn fails.
    pub fn start(
        listener: TcpListener,
        service: Arc<CacheService>,
        cfg: ServerConfig,
    ) -> io::Result<Server> {
        let io_threads = cfg.io_threads.max(1);
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        // Build every poller up front so an unsupported platform (or
        // fd exhaustion) errors here, not inside a spawned thread.
        let mut pollers = Vec::with_capacity(io_threads);
        for _ in 0..io_threads {
            pollers.push(Poller::new()?);
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::with_capacity(io_threads + 1);
        let mut senders = Vec::with_capacity(io_threads);

        for (i, poller) in pollers.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Connection>();
            senders.push(tx);
            let service = Arc::clone(&service);
            let shutdown = Arc::clone(&shutdown);
            let cfg = cfg.clone();
            let live = Arc::clone(&live);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("kway-io-{i}"))
                    .spawn(move || io_loop(poller, rx, service, shutdown, cfg, live, i as u64))?,
            );
        }

        {
            let shutdown = Arc::clone(&shutdown);
            let accepted = Arc::clone(&accepted);
            let max_conns = cfg.max_conns;
            threads.push(
                std::thread::Builder::new().name("kway-accept".into()).spawn(move || {
                    accept_loop(listener, senders, shutdown, accepted, service, max_conns, live)
                })?,
            );
        }

        Ok(Server { local_addr, shutdown, threads, accepted })
    }

    /// The bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connections accepted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Signal every thread to wind down and join them. Open
    /// connections are dropped (the harness has no draining story —
    /// clients are the load generator and the smoke tests).
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Safety net for early-return paths; `stop()` drains `threads`
        // so a normal stop makes this a no-op.
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Accept loop: non-blocking accepts, round-robin dispatch, max-conns
/// refusal. `live` counts dispatched-but-not-yet-closed connections
/// (incremented here, decremented by the owning io thread on every
/// close path).
fn accept_loop(
    listener: TcpListener,
    senders: Vec<mpsc::Sender<Connection>>,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    service: Arc<CacheService>,
    max_conns: usize,
    live: Arc<AtomicUsize>,
) {
    let mut next = 0usize;
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                // Request/response protocols on loopback: Nagle only
                // adds latency. Best-effort.
                let _ = stream.set_nodelay(true);
                accepted.fetch_add(1, Ordering::Relaxed);
                if max_conns > 0 && live.load(Ordering::Relaxed) >= max_conns {
                    // Answer-then-close: a fresh socket's send buffer is
                    // empty, so the nonblocking write virtually always
                    // lands whole; failure just means a silent close.
                    let _ = stream.write_all(b"SERVER_ERROR too many connections\r\n");
                    service.metrics().rejected_conns.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                live.fetch_add(1, Ordering::Relaxed);
                if senders[next % senders.len()].send(Connection::new(stream)).is_err() {
                    return; // io thread gone: shutting down
                }
                next = next.wrapping_add(1);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// A registered connection slot. The slot index is the poller token.
struct Slot {
    conn: Connection,
    fd: i32,
    want_write: bool,
    /// Last socket event on this connection (idle-timeout clock).
    last_activity: Instant,
    /// When the read buffer first held a partial request with no
    /// complete one to answer (request-deadline clock); cleared as
    /// soon as the buffer empties.
    partial_since: Option<Instant>,
}

/// One io thread: register incoming connections, poll, drive, and —
/// when configured — evict slow clients (write-queue byte cap) and
/// sweep idle / deadline-expired connections off the 20ms wait tick.
fn io_loop(
    poller: Poller,
    rx: mpsc::Receiver<Connection>,
    service: Arc<CacheService>,
    shutdown: Arc<AtomicBool>,
    cfg: ServerConfig,
    live: Arc<AtomicUsize>,
    seed: u64,
) {
    let mut slots: Vec<Option<Slot>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events = Vec::new();
    // Per-thread deterministic rng for the io_stall injection point.
    let mut rng = Rng::new(0xC4A0_5EED ^ seed);
    let mut ticks: u32 = 0;
    let sweeping = cfg.idle_timeout.is_some() || cfg.request_deadline.is_some();

    while !shutdown.load(Ordering::Relaxed) {
        // Adopt newly accepted connections.
        while let Ok(conn) = rx.try_recv() {
            let fd = conn.raw_fd();
            let slot = Slot {
                conn,
                fd,
                want_write: false,
                last_activity: Instant::now(),
                partial_since: None,
            };
            let token = match free.pop() {
                Some(i) => {
                    slots[i] = Some(slot);
                    i
                }
                None => {
                    slots.push(Some(slot));
                    slots.len() - 1
                }
            };
            if poller.add(fd, token as u64, false).is_err() {
                slots[token] = None;
                free.push(token);
                live.fetch_sub(1, Ordering::Relaxed);
            }
        }

        if poller.wait(&mut events, 20).is_err() {
            // A broken poller cannot recover; drop the thread's
            // connections and exit rather than spin.
            break;
        }

        // Injected scheduling hiccup before this event batch (inert
        // unless a fault plan is armed; see `kway::fault`).
        if let Some(faults) = &cfg.faults {
            if let Some(stall) = faults.io_stall_for(&mut rng) {
                std::thread::sleep(stall);
            }
        }

        for ev in &events {
            let token = ev.token as usize;
            let Some(slot) = slots.get_mut(token).and_then(|s| s.as_mut()) else {
                continue; // raced with removal
            };
            let readable = ev.readable || ev.closed;
            let status = slot.conn.handle(readable, &service);
            slot.last_activity = Instant::now();
            slot.partial_since = if slot.conn.has_buffered_request() {
                slot.partial_since.or(Some(slot.last_activity))
            } else {
                None
            };
            let fd = slot.fd;
            let prev_want_write = slot.want_write;
            // A peer that will not read while responses pile up is a
            // slow client; past the byte cap it forfeits the connection
            // (its queued responses are dropped with it).
            let too_slow = cfg.max_wq_bytes > 0 && slot.conn.queued_bytes() > cfg.max_wq_bytes;
            if !status.open || too_slow {
                if status.open {
                    service.metrics().evicted_slow.fetch_add(1, Ordering::Relaxed);
                }
                let _ = poller.delete(fd);
                slots[token] = None;
                free.push(token);
                live.fetch_sub(1, Ordering::Relaxed);
            } else if status.want_write != prev_want_write {
                if poller.modify(fd, token as u64, status.want_write).is_ok() {
                    slot.want_write = status.want_write;
                }
            }
        }

        ticks = ticks.wrapping_add(1);
        if sweeping && ticks % SWEEP_TICKS == 0 {
            let now = Instant::now();
            for i in 0..slots.len() {
                let Some(slot) = &slots[i] else { continue };
                let idle = cfg
                    .idle_timeout
                    .is_some_and(|t| now.duration_since(slot.last_activity) > t);
                let stalled = cfg.request_deadline.is_some_and(|t| {
                    slot.partial_since.is_some_and(|since| now.duration_since(since) > t)
                });
                if idle || stalled {
                    let _ = poller.delete(slot.fd);
                    slots[i] = None;
                    free.push(i);
                    live.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }

    // Surrender this thread's live-count share so a restarted server
    // sharing the counter (not a thing today, but cheap insurance)
    // never sees phantom connections.
    for _ in slots.iter().flatten() {
        live.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn start_server() -> (Server, Arc<CacheService>) {
        let cache = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..ServiceConfig::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            Server::start(listener, Arc::clone(&service), ServerConfig::default()).unwrap();
        (server, service)
    }

    #[test]
    fn serves_memcached_over_loopback() {
        let (server, _service) = start_server();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        stream.write_all(b"set 7 0 0 2\r\n42\r\nget 7\r\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["STORED", "VALUE 7 0 2", "42", "END"]);
        assert!(server.accepted() >= 1);
        server.stop();
    }

    #[test]
    fn serves_resp_and_memcached_concurrently() {
        let (server, _service) = start_server();
        let addr = server.local_addr();

        let mut resp = TcpStream::connect(addr).unwrap();
        resp.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        resp.write_all(b"*3\r\n$3\r\nSET\r\n$2\r\n10\r\n$2\r\n99\r\n").unwrap();
        let mut buf = [0u8; 64];
        let n = std::io::Read::read(&mut resp, &mut buf).unwrap();
        assert_eq!(&buf[..n], b"+OK\r\n");

        let mut mc = TcpStream::connect(addr).unwrap();
        mc.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        mc.write_all(b"get 10\r\n").unwrap();
        let mut reader = BufReader::new(mc.try_clone().unwrap());
        let mut lines = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line.trim_end().to_string());
        }
        assert_eq!(lines, vec!["VALUE 10 0 2", "99", "END"]);
        server.stop();
    }

    #[test]
    fn stop_is_idempotent_and_drops_open_connections() {
        let (server, _service) = start_server();
        let _open = TcpStream::connect(server.local_addr()).unwrap();
        server.stop();
    }

    fn start_with(cfg: ServerConfig) -> (Server, Arc<CacheService>) {
        let cache = Arc::new(KwWfsc::new(4096, 8, Policy::Lru));
        let service = Arc::new(CacheService::start(
            cache,
            ServiceConfig { workers: 2, ..ServiceConfig::default() },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server = Server::start(listener, Arc::clone(&service), cfg).unwrap();
        (server, service)
    }

    #[test]
    fn over_limit_connections_are_refused_with_an_answer() {
        let (server, service) =
            start_with(ServerConfig { max_conns: 1, ..ServerConfig::default() });
        // Occupy the single slot and prove it is being served.
        let mut first = TcpStream::connect(server.local_addr()).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        first.write_all(b"version\r\n").unwrap();
        let mut reader = BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("VERSION"), "{line:?}");
        // The next connection must be answered then closed.
        let second = TcpStream::connect(server.local_addr()).unwrap();
        second.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(second);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "SERVER_ERROR too many connections");
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "then EOF");
        assert!(service.metrics().rejected_conns.load(Ordering::Relaxed) >= 1);
        drop(first);
        server.stop();
    }

    #[test]
    fn idle_connections_are_reaped() {
        let (server, _service) = start_with(ServerConfig {
            idle_timeout: Some(Duration::from_millis(50)),
            ..ServerConfig::default()
        });
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        c.write_all(b"version\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("VERSION"), "{line:?}");
        // Go quiet: the sweep must close us well within the read timeout.
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut c, &mut buf).unwrap();
        assert_eq!(n, 0, "server must close the idle connection");
        server.stop();
    }

    #[test]
    fn stalled_partial_requests_hit_the_deadline() {
        let (server, _service) = start_with(ServerConfig {
            request_deadline: Some(Duration::from_millis(50)),
            ..ServerConfig::default()
        });
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // A request that never completes (no CRLF) — slowloris dribble.
        c.write_all(b"get 1").unwrap();
        let mut buf = [0u8; 16];
        let n = std::io::Read::read(&mut c, &mut buf).unwrap();
        assert_eq!(n, 0, "server must drop the stalled request");
        server.stop();
    }
}
