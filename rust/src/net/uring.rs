//! Completion-mode io_uring backend for the server event loop.
//!
//! Where [`crate::net::poll`] asks the kernel *which* sockets are ready
//! and then issues one `read`/`writev` per ready socket, this module
//! hands the kernel the operations themselves: each event-loop tick
//! submits a batch of `recv`/`writev` submission-queue entries (plus a
//! multishot `accept` on the acceptor) and harvests their completions —
//! so N ready connections cost **one** `io_uring_enter` instead of
//! ~2N+1 syscalls.
//!
//! The offline build has no `libc`/`io-uring` crate, so everything is
//! hand-laid against the kernel ABI in the style of [`poll`]: the
//! `io_uring_setup` (425) / `io_uring_enter` (426) / `io_uring_register`
//! (427) syscalls via raw `asm!`, `#[repr(C)]` ring structs, and the
//! SQ/CQ rings mapped with raw `mmap` at the kernel-defined magic
//! offsets. Memory ordering follows the kernel's contract: the SQ tail
//! is published with Release and the SQ head read with Acquire (the
//! kernel is the consumer), mirrored for the CQ where the kernel is the
//! producer.
//!
//! Capability is probed once per process ([`supported`]): the kernel
//! must accept `io_uring_setup`, report the `NODROP` and `EXT_ARG`
//! features (lossless CQ overflow + timed waits, both Linux ≥ 5.11),
//! and advertise the `RECV`/`WRITEV`/`ACCEPT`/`ASYNC_CANCEL` opcodes
//! via `IORING_REGISTER_PROBE`. `--backend auto` uses this to fall back
//! to epoll on kernels (or seccomp sandboxes) that refuse.
//!
//! [`poll`]: crate::net::poll

use std::io;

/// One harvested completion-queue entry.
///
/// `user_data` is echoed from the submission verbatim; `res` is the
/// operation's return value (bytes transferred, a new fd for `accept`,
/// or a negative errno).
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cqe {
    /// Caller-chosen tag from the matching SQE.
    pub user_data: u64,
    /// Syscall-style result: `>= 0` success value, `< 0` is `-errno`.
    pub res: i32,
    /// CQE flags; see [`CQE_F_MORE`].
    pub flags: u32,
}

/// Set on a multishot `accept` completion when the request remains
/// armed; absent means the kernel retired it and it must be re-armed.
pub const CQE_F_MORE: u32 = 1 << 1;

/// `-ECANCELED`: the result of an operation killed by `ASYNC_CANCEL`.
pub const ECANCELED: i32 = -125;

/// A kernel `struct iovec` for [`Ring::push_writev`]. Owned (rather
/// than borrowing like `IoSlice`) because the kernel reads the array
/// *asynchronously*: the caller must keep it alive and unmoved until
/// the completion arrives, which a borrow cannot express.
#[repr(C)]
#[derive(Debug, Clone, Copy, Default)]
pub struct IoVec {
    /// Pointer to the buffer (valid until the CQE is harvested).
    pub base: u64,
    /// Buffer length in bytes.
    pub len: u64,
}

impl IoVec {
    /// Point at `bytes`. Safety contract is the caller's: the slice's
    /// storage must outlive the submitted operation.
    pub fn from_slice(bytes: &[u8]) -> Self {
        Self { base: bytes.as_ptr() as u64, len: bytes.len() as u64 }
    }
}

pub use imp::{supported, Ring};

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod imp {
    use super::{Cqe, IoVec};
    use std::arch::asm;
    use std::io;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::OnceLock;

    const SYS_CLOSE: u64 = 3;
    const SYS_MMAP: u64 = 9;
    const SYS_MUNMAP: u64 = 11;
    const SYS_IO_URING_SETUP: u64 = 425;
    const SYS_IO_URING_ENTER: u64 = 426;
    const SYS_IO_URING_REGISTER: u64 = 427;

    // Feature bits reported by io_uring_setup.
    const FEAT_SINGLE_MMAP: u32 = 1 << 0;
    const FEAT_NODROP: u32 = 1 << 1;
    const FEAT_EXT_ARG: u32 = 1 << 8;

    // mmap offsets selecting which ring a map refers to.
    const OFF_SQ_RING: u64 = 0;
    const OFF_CQ_RING: u64 = 0x800_0000;
    const OFF_SQES: u64 = 0x1000_0000;

    const PROT_READ_WRITE: u64 = 0x3;
    const MAP_SHARED_POPULATE: u64 = 0x8001;

    // io_uring_enter flags.
    const ENTER_GETEVENTS: u32 = 1 << 0;
    const ENTER_EXT_ARG: u32 = 1 << 3;

    // Opcodes this backend submits.
    const OP_NOP: u8 = 0;
    const OP_WRITEV: u8 = 2;
    const OP_ACCEPT: u8 = 13;
    const OP_ASYNC_CANCEL: u8 = 14;
    const OP_RECV: u8 = 27;

    /// Multishot flag for `accept`, carried in `sqe.ioprio`.
    const ACCEPT_MULTISHOT: u16 = 1;

    /// Set by the kernel in the SQ flags word when CQEs are parked in
    /// the overflow backlog (NODROP); an extra GETEVENTS enter flushes
    /// them into the ring.
    const SQ_CQOVERFLOW: u32 = 1 << 1;

    const IORING_REGISTER_PROBE: u64 = 8;
    const PROBE_OP_SUPPORTED: u16 = 1;

    const ETIME: i32 = 62;
    const EINTR: i32 = 4;
    const EBUSY: i32 = 16;

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct SqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        flags: u32,
        dropped: u32,
        array: u32,
        resv1: u32,
        user_addr: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct CqOffsets {
        head: u32,
        tail: u32,
        ring_mask: u32,
        ring_entries: u32,
        overflow: u32,
        cqes: u32,
        flags: u32,
        resv1: u32,
        user_addr: u64,
    }

    /// `struct io_uring_params` (120 bytes, validated against the
    /// kernel with a C prototype before this port).
    #[repr(C)]
    #[derive(Clone, Copy, Default)]
    struct Params {
        sq_entries: u32,
        cq_entries: u32,
        flags: u32,
        sq_thread_cpu: u32,
        sq_thread_idle: u32,
        features: u32,
        wq_fd: u32,
        resv: [u32; 3],
        sq_off: SqOffsets,
        cq_off: CqOffsets,
    }

    /// `struct io_uring_sqe` (64 bytes). The kernel unions several
    /// fields; this layout names the members this backend uses and
    /// zero-fills the rest.
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct Sqe {
        opcode: u8,
        flags: u8,
        ioprio: u16,
        fd: i32,
        off: u64,
        addr: u64,
        len: u32,
        op_flags: u32,
        user_data: u64,
        buf_index: u16,
        personality: u16,
        splice_fd_in: i32,
        pad2: [u64; 2],
    }

    const SQE_ZERO: Sqe = Sqe {
        opcode: 0,
        flags: 0,
        ioprio: 0,
        fd: 0,
        off: 0,
        addr: 0,
        len: 0,
        op_flags: 0,
        user_data: 0,
        buf_index: 0,
        personality: 0,
        splice_fd_in: 0,
        pad2: [0; 2],
    };

    /// `struct io_uring_getevents_arg` for EXT_ARG timed waits.
    #[repr(C)]
    struct GeteventsArg {
        sigmask: u64,
        sigmask_sz: u32,
        pad: u32,
        ts: u64,
    }

    /// `struct __kernel_timespec`.
    #[repr(C)]
    struct KernelTimespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct ProbeOp {
        op: u8,
        resv: u8,
        flags: u16,
        resv2: u32,
    }

    /// `struct io_uring_probe` with the full 256-op table.
    #[repr(C)]
    struct Probe {
        last_op: u8,
        ops_len: u8,
        resv: u16,
        resv2: [u32; 3],
        ops: [ProbeOp; 256],
    }

    /// Six-argument raw syscall: like [`poll`]'s `syscall4` but with
    /// `r8`/`r9` for the 5th/6th arguments (`io_uring_enter` and `mmap`
    /// both take six).
    ///
    /// # Safety
    ///
    /// The caller must pass argument values valid for `nr`'s ABI.
    ///
    /// [`poll`]: crate::net::poll
    unsafe fn syscall6(nr: u64, a1: u64, a2: u64, a3: u64, a4: u64, a5: u64, a6: u64) -> i64 {
        let ret: i64;
        unsafe {
            asm!(
                "syscall",
                inlateout("rax") nr as i64 => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                in("r10") a4,
                in("r8") a5,
                in("r9") a6,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    fn check(ret: i64) -> io::Result<i64> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    /// Treat a `u32` field inside an mmap'd ring as an atomic. The
    /// pointer comes from kernel-supplied offsets into a live mapping,
    /// so it is valid and 4-aligned for the ring's lifetime.
    unsafe fn atomic_at<'a>(p: *mut u32) -> &'a AtomicU32 {
        unsafe { &*(p as *const AtomicU32) }
    }

    /// A completion-mode submission/completion ring pair.
    ///
    /// Not `Sync` — each io thread owns its ring exclusively, mirroring
    /// one-`Poller`-per-thread in the epoll backend. It *is* [`Send`]:
    /// the raw pointers target the ring mappings owned by the struct
    /// itself, so moving it across the spawn boundary is sound.
    #[derive(Debug)]
    pub struct Ring {
        fd: i32,
        sq_entries: u32,
        cq_entries: u32,
        // SQ ring mapping and the kernel-offset field pointers into it.
        sq_ring: *mut u8,
        sq_ring_sz: usize,
        sq_head: *mut u32,
        sq_tail: *mut u32,
        sq_mask: *mut u32,
        sq_flags: *mut u32,
        sq_array: *mut u32,
        // CQ ring mapping (aliases sq_ring under FEAT_SINGLE_MMAP).
        cq_ring: *mut u8,
        cq_ring_sz: usize,
        single_mmap: bool,
        cq_head: *mut u32,
        cq_tail: *mut u32,
        cq_mask: *mut u32,
        cqes: *mut Cqe,
        // SQE array mapping.
        sqes: *mut Sqe,
        sqes_sz: usize,
        /// SQEs pushed since the last successful enter.
        to_submit: u32,
        /// `io_uring_enter` calls issued — the syscall-accounting feed.
        syscalls: u64,
    }

    // SAFETY: all raw pointers reference the mmap'd rings owned (and
    // unmapped) by this struct; nothing is tied to the creating thread.
    unsafe impl Send for Ring {}

    impl Ring {
        /// Set up a ring with at least `entries` SQ slots (the kernel
        /// rounds up to a power of two and sizes the CQ at 2× SQ).
        ///
        /// Fails with `Unsupported` when the kernel lacks io_uring or
        /// the `NODROP`/`EXT_ARG` features this backend's overflow and
        /// timed-wait handling depend on.
        pub fn new(entries: u32) -> io::Result<Self> {
            let mut p = Params::default();
            let ret = unsafe {
                syscall6(SYS_IO_URING_SETUP, entries as u64, &mut p as *mut Params as u64, 0, 0, 0, 0)
            };
            let fd = check(ret).map_err(|e| {
                if e.raw_os_error() == Some(38) {
                    io::Error::new(io::ErrorKind::Unsupported, "kernel has no io_uring (ENOSYS)")
                } else {
                    e
                }
            })? as i32;
            let need = FEAT_NODROP | FEAT_EXT_ARG;
            if p.features & need != need {
                unsafe { syscall6(SYS_CLOSE, fd as u64, 0, 0, 0, 0, 0) };
                return Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "io_uring lacks NODROP/EXT_ARG (kernel < 5.11)",
                ));
            }

            let mut sq_sz = p.sq_off.array as usize + p.sq_entries as usize * 4;
            let mut cq_sz = p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<Cqe>();
            let single = p.features & FEAT_SINGLE_MMAP != 0;
            if single {
                sq_sz = sq_sz.max(cq_sz);
                cq_sz = sq_sz;
            }
            let map = |len: usize, off: u64| -> io::Result<*mut u8> {
                let ret = unsafe {
                    syscall6(
                        SYS_MMAP,
                        0,
                        len as u64,
                        PROT_READ_WRITE,
                        MAP_SHARED_POPULATE,
                        fd as u64,
                        off,
                    )
                };
                check(ret).map(|a| a as *mut u8)
            };
            let close_on_err = |e: io::Error| {
                unsafe { syscall6(SYS_CLOSE, fd as u64, 0, 0, 0, 0, 0) };
                e
            };
            let sq_ring = map(sq_sz, OFF_SQ_RING).map_err(close_on_err)?;
            let cq_ring = if single { sq_ring } else { map(cq_sz, OFF_CQ_RING).map_err(close_on_err)? };
            let sqes_sz = p.sq_entries as usize * std::mem::size_of::<Sqe>();
            let sqes = map(sqes_sz, OFF_SQES).map_err(close_on_err)? as *mut Sqe;

            unsafe {
                Ok(Self {
                    fd,
                    sq_entries: p.sq_entries,
                    cq_entries: p.cq_entries,
                    sq_ring,
                    sq_ring_sz: sq_sz,
                    sq_head: sq_ring.add(p.sq_off.head as usize) as *mut u32,
                    sq_tail: sq_ring.add(p.sq_off.tail as usize) as *mut u32,
                    sq_mask: sq_ring.add(p.sq_off.ring_mask as usize) as *mut u32,
                    sq_flags: sq_ring.add(p.sq_off.flags as usize) as *mut u32,
                    sq_array: sq_ring.add(p.sq_off.array as usize) as *mut u32,
                    cq_ring,
                    cq_ring_sz: cq_sz,
                    single_mmap: single,
                    cq_head: cq_ring.add(p.cq_off.head as usize) as *mut u32,
                    cq_tail: cq_ring.add(p.cq_off.tail as usize) as *mut u32,
                    cq_mask: cq_ring.add(p.cq_off.ring_mask as usize) as *mut u32,
                    cqes: cq_ring.add(p.cq_off.cqes as usize) as *mut Cqe,
                    sqes,
                    sqes_sz,
                    to_submit: 0,
                    syscalls: 0,
                })
            }
        }

        /// SQ slots the ring was created with.
        pub fn sq_entries(&self) -> u32 {
            self.sq_entries
        }

        /// CQ slots (relevant to overflow tests; NODROP means overflow
        /// is a backlog, not a loss).
        pub fn cq_entries(&self) -> u32 {
            self.cq_entries
        }

        /// Claim the next SQE, or `None` when the SQ is full (the
        /// caller should `submit()` and retry).
        fn next_sqe(&mut self, user_data: u64) -> Option<&mut Sqe> {
            let head = unsafe { atomic_at(self.sq_head) }.load(Ordering::Acquire);
            let tail = unsafe { *self.sq_tail };
            if tail.wrapping_sub(head) >= self.sq_entries {
                return None;
            }
            let idx = tail & unsafe { *self.sq_mask };
            unsafe {
                let sqe = &mut *self.sqes.add(idx as usize);
                *sqe = SQE_ZERO;
                sqe.user_data = user_data;
                // Identity-map the dispatch array: slot idx holds idx.
                *self.sq_array.add(idx as usize) = idx;
                Some(sqe)
            }
        }

        /// Publish the claimed SQE to the kernel (Release pairs with
        /// the kernel's Acquire of the tail).
        fn commit_sqe(&mut self) {
            let tail = unsafe { *self.sq_tail };
            unsafe { atomic_at(self.sq_tail) }.store(tail.wrapping_add(1), Ordering::Release);
            self.to_submit += 1;
        }

        /// Queue a no-op (tests and wakeup plumbing). Returns `false`
        /// when the SQ is full.
        pub fn push_nop(&mut self, user_data: u64) -> bool {
            match self.next_sqe(user_data) {
                Some(sqe) => {
                    sqe.opcode = OP_NOP;
                    self.commit_sqe();
                    true
                }
                None => false,
            }
        }

        /// Queue a `recv` into `buf`. The buffer must stay alive and
        /// unmoved until the completion is harvested.
        pub fn push_recv(&mut self, fd: i32, buf: &mut [u8], user_data: u64) -> bool {
            let (addr, len) = (buf.as_mut_ptr() as u64, buf.len() as u32);
            match self.next_sqe(user_data) {
                Some(sqe) => {
                    sqe.opcode = OP_RECV;
                    sqe.fd = fd;
                    sqe.addr = addr;
                    sqe.len = len;
                    self.commit_sqe();
                    true
                }
                None => false,
            }
        }

        /// Queue a gather-write of `iovecs`. The iovec array *and* the
        /// buffers it points at must stay alive and unmoved until the
        /// completion is harvested.
        pub fn push_writev(&mut self, fd: i32, iovecs: &[IoVec], user_data: u64) -> bool {
            let (addr, len) = (iovecs.as_ptr() as u64, iovecs.len() as u32);
            match self.next_sqe(user_data) {
                Some(sqe) => {
                    sqe.opcode = OP_WRITEV;
                    sqe.fd = fd;
                    sqe.addr = addr;
                    sqe.len = len;
                    self.commit_sqe();
                    true
                }
                None => false,
            }
        }

        /// Queue an `accept` on listener `fd`. Multishot keeps the
        /// request armed across accepts (one SQE, many CQEs) — but is
        /// newer (5.19) than the probed baseline, so callers must
        /// handle an `-EINVAL` completion by re-arming single-shot.
        pub fn push_accept(&mut self, fd: i32, multishot: bool, user_data: u64) -> bool {
            match self.next_sqe(user_data) {
                Some(sqe) => {
                    sqe.opcode = OP_ACCEPT;
                    sqe.fd = fd;
                    if multishot {
                        sqe.ioprio = ACCEPT_MULTISHOT;
                    }
                    self.commit_sqe();
                    true
                }
                None => false,
            }
        }

        /// Queue a cancellation of the in-flight operation tagged
        /// `target_user_data`; the victim completes with `-ECANCELED`.
        pub fn push_cancel(&mut self, target_user_data: u64, user_data: u64) -> bool {
            match self.next_sqe(user_data) {
                Some(sqe) => {
                    sqe.opcode = OP_ASYNC_CANCEL;
                    sqe.addr = target_user_data;
                    self.commit_sqe();
                    true
                }
                None => false,
            }
        }

        fn enter(&mut self, min_complete: u32, flags: u32, arg: u64, argsz: u64) -> io::Result<u32> {
            let to_submit = self.to_submit;
            let ret = unsafe {
                syscall6(
                    SYS_IO_URING_ENTER,
                    self.fd as u64,
                    to_submit as u64,
                    min_complete as u64,
                    flags as u64,
                    arg,
                    argsz,
                )
            };
            self.syscalls += 1;
            if ret < 0 {
                let errno = -ret as i32;
                // ETIME: the wait timed out; EINTR: a signal broke the
                // wait. Both happen *after* submission, so the pushed
                // SQEs are consumed.
                if errno == ETIME || errno == EINTR {
                    self.to_submit = 0;
                    return Ok(0);
                }
                // EBUSY: the CQ backlog blocks submission; keep
                // `to_submit` so the caller harvests and retries.
                if errno == EBUSY {
                    return Ok(0);
                }
                return Err(io::Error::from_raw_os_error(errno));
            }
            let submitted = (ret as u32).min(self.to_submit);
            self.to_submit -= submitted;
            Ok(submitted)
        }

        /// Submit pending SQEs without waiting (used when the SQ fills
        /// mid-tick). No syscall if nothing is pending.
        pub fn submit(&mut self) -> io::Result<()> {
            if self.to_submit == 0 {
                return Ok(());
            }
            self.enter(0, 0, 0, 0).map(|_| ())
        }

        /// The one-syscall tick: submit everything pending and wait up
        /// to `timeout_ms` for at least `wait_nr` completions.
        pub fn submit_and_wait(&mut self, wait_nr: u32, timeout_ms: u32) -> io::Result<()> {
            let ts = KernelTimespec {
                tv_sec: (timeout_ms / 1000) as i64,
                tv_nsec: (timeout_ms % 1000) as i64 * 1_000_000,
            };
            let arg = GeteventsArg {
                sigmask: 0,
                sigmask_sz: 0,
                pad: 0,
                ts: &ts as *const KernelTimespec as u64,
            };
            self.enter(
                wait_nr,
                ENTER_GETEVENTS | ENTER_EXT_ARG,
                &arg as *const GeteventsArg as u64,
                std::mem::size_of::<GeteventsArg>() as u64,
            )
            .map(|_| ())
        }

        /// Drain all available CQEs into `out` (cleared first). When
        /// the kernel flags an overflow backlog (NODROP), extra
        /// GETEVENTS enters flush it so no completion is ever lost.
        pub fn harvest(&mut self, out: &mut Vec<Cqe>) -> io::Result<usize> {
            out.clear();
            let mut flushes = 0u32;
            loop {
                let before = out.len();
                let mut head = unsafe { *self.cq_head };
                let tail = unsafe { atomic_at(self.cq_tail) }.load(Ordering::Acquire);
                let mask = unsafe { *self.cq_mask };
                while head != tail {
                    out.push(unsafe { *self.cqes.add((head & mask) as usize) });
                    head = head.wrapping_add(1);
                }
                unsafe { atomic_at(self.cq_head) }.store(head, Ordering::Release);
                let overflowed = unsafe { atomic_at(self.sq_flags) }.load(Ordering::Acquire)
                    & SQ_CQOVERFLOW
                    != 0;
                if !overflowed {
                    break;
                }
                // A flush that moved nothing into the ring means the
                // backlog will drain on later ticks; don't spin. The
                // cap bounds the loop even against a pathological
                // kernel that never clears the flag.
                if (flushes > 0 && out.len() == before) || flushes >= 64 {
                    break;
                }
                // Room was just freed; ask the kernel to flush the
                // overflow backlog into the ring and drain again.
                self.enter(0, ENTER_GETEVENTS, 0, 0)?;
                flushes += 1;
            }
            Ok(out.len())
        }

        /// Take and reset the enter-syscall count (feeds
        /// `ServiceMetrics::io_syscalls`).
        pub fn take_syscalls(&mut self) -> u64 {
            std::mem::take(&mut self.syscalls)
        }
    }

    impl Drop for Ring {
        fn drop(&mut self) {
            // Closing the ring fd cancels any still-inflight ops.
            unsafe {
                syscall6(SYS_MUNMAP, self.sq_ring as u64, self.sq_ring_sz as u64, 0, 0, 0, 0);
                if !self.single_mmap {
                    syscall6(SYS_MUNMAP, self.cq_ring as u64, self.cq_ring_sz as u64, 0, 0, 0, 0);
                }
                syscall6(SYS_MUNMAP, self.sqes as u64, self.sqes_sz as u64, 0, 0, 0, 0);
                syscall6(SYS_CLOSE, self.fd as u64, 0, 0, 0, 0, 0);
            }
        }
    }

    /// Whether this kernel supports everything the uring backend needs.
    /// Probed once per process: ring setup must succeed with
    /// NODROP+EXT_ARG, and `IORING_REGISTER_PROBE` must report the
    /// `WRITEV`/`ACCEPT`/`ASYNC_CANCEL`/`RECV` opcodes.
    pub fn supported() -> bool {
        static PROBED: OnceLock<bool> = OnceLock::new();
        *PROBED.get_or_init(|| {
            let ring = match Ring::new(8) {
                Ok(r) => r,
                Err(_) => return false,
            };
            let mut probe: Probe = unsafe { std::mem::zeroed() };
            let ret = unsafe {
                syscall6(
                    SYS_IO_URING_REGISTER,
                    ring.fd as u64,
                    IORING_REGISTER_PROBE,
                    &mut probe as *mut Probe as u64,
                    256,
                    0,
                    0,
                )
            };
            if ret < 0 {
                return false;
            }
            [OP_WRITEV, OP_ACCEPT, OP_ASYNC_CANCEL, OP_RECV].iter().all(|&op| {
                op <= probe.last_op && probe.ops[op as usize].flags & PROBE_OP_SUPPORTED != 0
            })
        })
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod imp {
    //! Stub with the full [`Ring`] surface so callers compile on every
    //! platform; construction honestly fails and `supported()` is
    //! `false`, which steers `--backend auto` to epoll (itself also
    //! unavailable off linux/x86_64 — the server reports Unsupported).
    use super::{Cqe, IoVec};
    use std::io;

    /// Never-constructed placeholder ring.
    #[derive(Debug)]
    pub struct Ring {
        _never: std::convert::Infallible,
    }

    impl Ring {
        /// Always `Unsupported` on this platform.
        pub fn new(_entries: u32) -> io::Result<Self> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "io_uring backend is linux/x86_64 only",
            ))
        }

        /// Unreachable (no value exists).
        pub fn sq_entries(&self) -> u32 {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn cq_entries(&self) -> u32 {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn push_nop(&mut self, _user_data: u64) -> bool {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn push_recv(&mut self, _fd: i32, _buf: &mut [u8], _user_data: u64) -> bool {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn push_writev(&mut self, _fd: i32, _iovecs: &[IoVec], _user_data: u64) -> bool {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn push_accept(&mut self, _fd: i32, _multishot: bool, _user_data: u64) -> bool {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn push_cancel(&mut self, _target: u64, _user_data: u64) -> bool {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn submit(&mut self) -> io::Result<()> {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn submit_and_wait(&mut self, _wait_nr: u32, _timeout_ms: u32) -> io::Result<()> {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn harvest(&mut self, _out: &mut Vec<Cqe>) -> io::Result<usize> {
            unreachable!("io_uring stub ring cannot exist")
        }

        /// Unreachable (no value exists).
        pub fn take_syscalls(&mut self) -> u64 {
            unreachable!("io_uring stub ring cannot exist")
        }
    }

    /// io_uring never exists off linux/x86_64.
    pub fn supported() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skip (with a visible reason) on kernels/sandboxes without
    /// io_uring — mirrors the integration suite's skip policy.
    fn require_uring(test: &str) -> bool {
        if supported() {
            true
        } else {
            eprintln!("skipping {test}: kernel/sandbox has no usable io_uring");
            false
        }
    }

    #[test]
    fn setup_mmap_nop_roundtrip() {
        if !require_uring("setup_mmap_nop_roundtrip") {
            return;
        }
        let mut ring = Ring::new(8).expect("io_uring_setup");
        assert!(ring.sq_entries() >= 8);
        assert!(ring.push_nop(0xAB));
        assert!(ring.push_nop(0xCD));
        ring.submit_and_wait(2, 1000).expect("enter");
        let mut cqes = Vec::new();
        ring.harvest(&mut cqes).expect("harvest");
        let mut tags: Vec<u64> = cqes.iter().map(|c| c.user_data).collect();
        tags.sort_unstable();
        assert_eq!(tags, vec![0xAB, 0xCD]);
        assert!(cqes.iter().all(|c| c.res == 0), "NOP must complete with res=0");
        assert!(ring.take_syscalls() >= 1, "the tick must be accounted");
    }

    #[test]
    fn sq_full_applies_backpressure() {
        if !require_uring("sq_full_applies_backpressure") {
            return;
        }
        let mut ring = Ring::new(2).expect("io_uring_setup");
        let entries = ring.sq_entries();
        let mut pushed = 0u32;
        for i in 0..entries + 8 {
            if !ring.push_nop(i as u64) {
                break;
            }
            pushed += 1;
        }
        assert_eq!(pushed, entries, "pushes past the SQ size must report full");
        // Submitting frees every slot for the next batch.
        ring.submit().expect("submit");
        assert!(ring.push_nop(999), "SQ must have space after submit");
    }

    #[test]
    fn cq_overflow_backlog_is_lossless() {
        if !require_uring("cq_overflow_backlog_is_lossless") {
            return;
        }
        // entries=2 → CQ of 4; flooding 12 NOPs without harvesting
        // forces the NODROP overflow backlog path.
        let mut ring = Ring::new(2).expect("io_uring_setup");
        let total: u32 = 12;
        let mut submitted = 0u32;
        while submitted < total {
            if ring.push_nop(1000 + submitted as u64) {
                submitted += 1;
            } else {
                ring.submit().expect("submit");
            }
        }
        ring.submit().expect("final submit");
        assert!(total > ring.cq_entries(), "flood must exceed the CQ");

        let mut got: Vec<u64> = Vec::new();
        let mut cqes = Vec::new();
        for _ in 0..100 {
            ring.harvest(&mut cqes).expect("harvest");
            got.extend(cqes.iter().map(|c| c.user_data));
            if got.len() as u32 >= total {
                break;
            }
            ring.submit_and_wait(1, 100).expect("enter");
        }
        got.sort_unstable();
        let want: Vec<u64> = (0..total as u64).map(|i| 1000 + i).collect();
        assert_eq!(got, want, "every flooded completion must eventually surface");
    }

    #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
    #[test]
    fn unsupported_platform_fails_fast() {
        assert!(!supported());
        let err = Ring::new(8).expect_err("no ring off linux/x86_64");
        assert_eq!(err.kind(), std::io::ErrorKind::Unsupported);
    }
}
