//! TCP wire-protocol front end: pipelined memcached/RESP serving fused
//! with the batched cache path.
//!
//! The paper's throughput claims are about *serving* concurrent traffic;
//! this module gives the reproduction its network path. `kway serve
//! --listen <addr>` runs a non-blocking TCP server that speaks two
//! protocols on the same port (auto-detected from the first byte of a
//! connection: `*` opens a RESP frame, anything else is a memcached text
//! line):
//!
//! * **memcached text** — `get`/`gets` (multi-key), `set`, `add`,
//!   `cas`, `delete`, `touch`, `version`, `quit`, with `noreply`;
//! * **RESP** (the redis serialization protocol, arrays-of-bulk-strings
//!   subset) — `GET`, `SET [EX s|PX ms]`, `MGET`, `MSET`, `DEL`,
//!   `EXPIRE`, `PING`, `QUIT`.
//!
//! The core performance move is **pipeline→batch fusion** ([`conn`]):
//! one socket read drains *every* complete pipelined request into a
//! command stream, and consecutive reads (resp. writes) are accumulated
//! and executed as a single [`CacheService::get_batch`] /
//! [`CacheService::put_batch_with`] scatter/gather call — so TCP
//! pipelining composes with the cache's prefetching SIMD-probed batched
//! path, admission, TTL and resize. Responses are queued per connection
//! and flushed with vectored `writev` ([`buf::WriteQueue`]).
//!
//! The event loop ([`server`]) has two backends behind one seam
//! (`--backend epoll|uring|auto`, [`server::BackendChoice`]): raw-
//! syscall **epoll** readiness mode ([`poll`], in the style of
//! [`crate::util::affinity`] — the offline build has no `libc`/`mio`),
//! one poller per io thread, connections handed out round-robin by a
//! non-blocking acceptor; and raw-syscall **io_uring** completion mode
//! ([`uring`]), where each tick submits batched `recv`/`writev` SQEs
//! (plus a multishot `accept` on the acceptor) and harvests CQEs, so N
//! ready connections cost one `io_uring_enter` instead of ~2N+1
//! syscalls. Both backends drive the *same* [`Connection`] session
//! core, which is what keeps them byte-identical on the wire. `auto`
//! probes at startup and falls back to epoll on kernels without
//! io_uring. Off linux/x86_64 the server honestly reports itself
//! unsupported; the codecs, buffers and the load generator
//! ([`loadgen`]) are pure `std::net` and run everywhere.
//!
//! Wire keys and values map onto the crate's `u64`-keyed caches as
//! follows (DESIGN.md §Network front end): a key that is plain ASCII
//! decimal (and < 2^63) is used numerically, any other key is hashed
//! (xxh64) with the top bit forced so the two spaces cannot collide.
//! Values are **binary-safe bytes**: over a byte-value cache
//! (`--value-bytes`, DESIGN.md §Value store) any payload up to
//! [`MAX_VALUE_LEN`] round-trips verbatim — memcached data blocks are
//! length-framed (never CRLF-scanned) and RESP bulk strings are length-
//! prefixed by construction. Over a word-only cache the pre-slab
//! contract still holds: values must be ASCII-decimal `u64`, anything
//! else is a client error, because the cache stores fixed-width words.
//!
//! [`CacheService::get_batch`]: crate::coordinator::CacheService::get_batch
//! [`CacheService::put_batch_with`]: crate::coordinator::CacheService::put_batch_with

pub mod buf;
pub mod conn;
pub mod loadgen;
pub mod memcached;
pub mod poll;
pub mod resp;
pub mod server;
pub mod uring;

pub use conn::Connection;
pub use loadgen::{LoadgenConfig, LoadgenResult, WireProto};
pub use server::{BackendChoice, Server, ServerConfig};

use std::time::Duration;

/// Longest accepted key, in bytes (memcached's protocol limit, adopted
/// for both protocols so one cap bounds every per-key allocation).
pub const MAX_KEY_LEN: usize = 250;

/// Longest accepted command line (memcached) before the decoder declares
/// the stream desynchronized and drops the connection.
pub const MAX_LINE_LEN: usize = 8 * 1024;

/// Largest accepted `set` data block / RESP bulk string: 1 MiB, the
/// slab store's largest item class (memcached's classic default cap).
/// Bounds per-frame memory for malformed or hostile frames too.
pub const MAX_VALUE_LEN: usize = 1 << 20;

/// A key as it appeared on the wire, plus its `u64` cache identity.
///
/// The original bytes are retained because memcached `VALUE` response
/// lines must echo the key text verbatim; the cache itself only ever
/// sees [`WireKey::id`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireKey {
    /// The cache key: the decimal value for numeric keys, else a hash
    /// with the top bit forced (see [`WireKey::from_bytes`]).
    pub id: u64,
    /// The verbatim wire bytes, echoed in memcached `VALUE` lines.
    pub text: Vec<u8>,
}

impl WireKey {
    /// Map wire bytes to a cache key. ASCII-decimal keys below 2^63 map
    /// to their numeric value (so `kway loadgen` and the in-process
    /// harnesses address the same keyspace); everything else maps to
    /// `xxh64(bytes) | 1<<63` — the forced top bit keeps hashed keys
    /// disjoint from the numeric space, at the cost of (astronomically
    /// unlikely) hash collisions *within* the non-numeric space.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        let numeric = std::str::from_utf8(bytes)
            .ok()
            .filter(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()))
            .and_then(|s| s.parse::<u64>().ok())
            .filter(|&n| n < (1u64 << 63));
        let id = match numeric {
            Some(n) => n,
            None => crate::util::hash::xxh64(bytes, 0xF00D) | (1u64 << 63),
        };
        Self { id, text: bytes.to_vec() }
    }
}

/// Parse an ASCII-decimal `u64` value payload (the only value encoding
/// the fixed-width cache words can hold).
pub fn parse_value(bytes: &[u8]) -> Option<u64> {
    std::str::from_utf8(bytes)
        .ok()
        .filter(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()))
        .and_then(|s| s.parse::<u64>().ok())
}

/// One decoded request, shared by both protocol codecs so the fusion
/// executor ([`conn`]) is written once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Command {
    /// A read of one or more keys: memcached `get`/`gets`, RESP
    /// `GET`/`MGET`. Consecutive `Read`s fuse into one `get_batch`.
    Read {
        /// Keys in request order.
        keys: Vec<WireKey>,
        /// memcached `gets`: echo a cas token on each `VALUE` line.
        cas: bool,
        /// RESP `GET` (single bulk reply) vs `MGET` (array reply).
        single: bool,
    },
    /// An unconditional store: memcached `set`, RESP `SET`. Consecutive
    /// `Write`s with identical effective options fuse into one
    /// `put_batch_with`.
    Write {
        /// The key to store under.
        key: WireKey,
        /// The raw value payload (binary-safe). A byte-value cache
        /// stores it verbatim; a word-only cache requires ASCII-decimal
        /// `u64` (checked at execution, not decode).
        value: Vec<u8>,
        /// Entry TTL; `None` defers to the service default.
        ttl: Option<Duration>,
        /// memcached `add`: store only if the key is absent (read-
        /// modify-write; executes unfused).
        add_only: bool,
        /// memcached `noreply`: suppress the response line.
        noreply: bool,
    },
    /// memcached `cas`: store only if the entry's version token still
    /// matches the one a prior `gets` returned — the entry's stored
    /// word (a generation-stamped slab handle on a byte-value cache,
    /// the value itself on a word cache). Read-modify-write; executes
    /// unfused, best-effort under concurrency like `add`/`touch`.
    Cas {
        /// The key to conditionally store under.
        key: WireKey,
        /// The raw replacement payload (binary-safe; same executor
        /// rules as [`Command::Write`]).
        value: Vec<u8>,
        /// Entry TTL; `None` defers to the service default.
        ttl: Option<Duration>,
        /// The version token from `gets` to compare against.
        token: u64,
        /// memcached `noreply`.
        noreply: bool,
    },
    /// RESP `MSET`: unconditional stores of several pairs (one fused
    /// `put_batch_with`).
    WriteMany {
        /// `(key, raw value)` pairs in request order.
        items: Vec<(WireKey, Vec<u8>)>,
    },
    /// memcached `delete` (one key) / RESP `DEL` (many): tombstone
    /// present keys with a born-expired entry (DESIGN.md §Network
    /// front end).
    Delete {
        /// Keys to remove.
        keys: Vec<WireKey>,
        /// memcached `noreply`.
        noreply: bool,
    },
    /// memcached `touch` / RESP `EXPIRE`: re-stamp a present entry's
    /// TTL (get + put_with; best-effort under concurrency).
    Touch {
        /// The key to re-stamp.
        key: WireKey,
        /// New TTL; `None` makes the entry immortal (memcached
        /// `touch <key> 0`).
        ttl: Option<Duration>,
        /// memcached `noreply`.
        noreply: bool,
    },
    /// memcached `stats` / RESP `INFO`: dump the service metrics
    /// ([`crate::coordinator::ServiceMetrics::stat_pairs`]) as `STAT
    /// name value` lines + `END` (memcached) or one `name:value`-lines
    /// bulk string (RESP).
    Stats,
    /// RESP `PING` → `+PONG`.
    Ping,
    /// memcached `version` → `VERSION <crate version>`.
    Version,
    /// Close the connection after flushing queued responses.
    Quit,
    /// A recoverable protocol error: respond with `line` and keep the
    /// connection (framing was re-synchronized by the decoder).
    Bad {
        /// The full response line, without the trailing CRLF.
        line: String,
    },
}

/// A protocol violation after which the byte stream cannot be re-framed
/// (overlong line, corrupt RESP header, …). The connection reports the
/// error and closes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FatalProtocolError(pub String);

impl std::fmt::Display for FatalProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fatal protocol error: {}", self.0)
    }
}

impl std::error::Error for FatalProtocolError {}

/// Convert a memcached `exptime` (relative seconds) to an entry TTL:
/// `0` = immortal, negative = already expired (a born-dead tombstone).
/// Deviation from memcached: values > 30 days are *not* reinterpreted
/// as absolute unix timestamps — the harness has no use for wall-clock
/// expiry and the relative reading keeps loadgen runs reproducible.
pub fn exptime_to_ttl(exptime: i64) -> Option<Duration> {
    match exptime {
        0 => None,
        t if t < 0 => Some(Duration::ZERO),
        t => Some(Duration::from_secs(t as u64)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_keys_map_to_their_value() {
        assert_eq!(WireKey::from_bytes(b"0").id, 0);
        assert_eq!(WireKey::from_bytes(b"42").id, 42);
        assert_eq!(WireKey::from_bytes(b"9007199254740993").id, 9007199254740993);
        assert_eq!(WireKey::from_bytes(b"123").text, b"123".to_vec());
    }

    #[test]
    fn non_numeric_keys_hash_into_the_high_space() {
        for raw in [&b"user:42"[..], b"", b"-1", b"+5", b"18446744073709551615", b"abc"] {
            let k = WireKey::from_bytes(raw);
            assert!(k.id >= (1u64 << 63), "{raw:?} must land in the hashed space");
        }
        // Same bytes, same id; different bytes, (almost surely) different id.
        assert_eq!(WireKey::from_bytes(b"user:42").id, WireKey::from_bytes(b"user:42").id);
        assert_ne!(WireKey::from_bytes(b"user:42").id, WireKey::from_bytes(b"user:43").id);
    }

    #[test]
    fn numeric_keys_at_the_boundary() {
        // 2^63 - 1 is the last direct-mapped key; 2^63 and up hash.
        assert_eq!(WireKey::from_bytes(b"9223372036854775807").id, (1u64 << 63) - 1);
        assert!(WireKey::from_bytes(b"9223372036854775808").id >= (1u64 << 63));
    }

    #[test]
    fn value_parsing_is_strict_decimal() {
        assert_eq!(parse_value(b"0"), Some(0));
        assert_eq!(parse_value(b"18446744073709551615"), Some(u64::MAX));
        assert_eq!(parse_value(b""), None);
        assert_eq!(parse_value(b"-1"), None);
        assert_eq!(parse_value(b"+1"), None);
        assert_eq!(parse_value(b"1.5"), None);
        assert_eq!(parse_value(b"abc"), None);
        assert_eq!(parse_value(b"18446744073709551616"), None); // u64::MAX + 1
    }

    #[test]
    fn exptime_mapping() {
        assert_eq!(exptime_to_ttl(0), None);
        assert_eq!(exptime_to_ttl(-1), Some(Duration::ZERO));
        assert_eq!(exptime_to_ttl(5), Some(Duration::from_secs(5)));
    }
}
