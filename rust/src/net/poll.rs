//! Readiness polling for the server event loop.
//!
//! [`Poller`] wraps Linux epoll behind a deliberately small, mio-shaped
//! surface — `add` / `modify` / `delete` / `wait` over opaque `u64`
//! tokens — so an io_uring (or kqueue) backend can slot in later as a
//! second [`Backend`] variant without touching the connection layer.
//!
//! The offline build has no `libc`, so every call is a raw `syscall`
//! instruction in the style of [`crate::util::affinity`]. Unlike
//! affinity's best-effort booleans, polling failures are real errors:
//! they surface as `io::Error` (decoded from the negative errno), and a
//! platform without the implementation reports `ErrorKind::Unsupported`
//! from [`Poller::new`] instead of silently never delivering events.

use std::io;

/// Readiness delivered by [`Poller::wait`] for one registered fd.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// The token the fd was registered with.
    pub token: u64,
    /// The socket has bytes to read (or a pending error to collect).
    pub readable: bool,
    /// The socket accepts writes again after an earlier short write.
    pub writable: bool,
    /// The peer closed or the socket errored; a read will observe
    /// EOF/error. Treated as readable by the connection layer.
    pub closed: bool,
}

/// Readiness poller: epoll today, shaped so io_uring can slot in.
#[derive(Debug)]
pub struct Poller {
    backend: Backend,
}

#[derive(Debug)]
enum Backend {
    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    Epoll(imp::Epoll),
    // Never constructed: `Poller::new` fails before building one. The
    // variant exists so the match arms compile on every platform.
    #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
    #[allow(dead_code)]
    Unsupported,
}

impl Poller {
    /// Create a poller. On platforms without an implementation this
    /// returns `ErrorKind::Unsupported` — callers (the server) fail
    /// fast instead of accepting connections they can never poll.
    pub fn new() -> io::Result<Self> {
        #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
        {
            Ok(Self { backend: Backend::Epoll(imp::Epoll::new()?) })
        }
        #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
        {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "no readiness-poll backend on this platform (epoll is linux/x86_64 only)",
            ))
        }
    }

    /// Register `fd` under `token`. Read + peer-hangup interest is
    /// always on; `want_write` adds write-readiness (used only while a
    /// connection has queued response bytes).
    pub fn add(&self, fd: i32, token: u64, want_write: bool) -> io::Result<()> {
        match &self.backend {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Backend::Epoll(e) => e.ctl(imp::EPOLL_CTL_ADD, fd, token, want_write),
            #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
            Backend::Unsupported => unsupported(),
        }
    }

    /// Re-register `fd` with a new write-interest setting.
    pub fn modify(&self, fd: i32, token: u64, want_write: bool) -> io::Result<()> {
        match &self.backend {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Backend::Epoll(e) => e.ctl(imp::EPOLL_CTL_MOD, fd, token, want_write),
            #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
            Backend::Unsupported => unsupported(),
        }
    }

    /// Deregister `fd`. Must be called before the fd is closed.
    pub fn delete(&self, fd: i32) -> io::Result<()> {
        match &self.backend {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Backend::Epoll(e) => e.ctl(imp::EPOLL_CTL_DEL, fd, 0, false),
            #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
            Backend::Unsupported => unsupported(),
        }
    }

    /// Block up to `timeout_ms` for readiness, appending into `events`
    /// (cleared first). An interrupting signal (`EINTR`) returns an
    /// empty set rather than an error, like mio.
    pub fn wait(&self, events: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        events.clear();
        match &self.backend {
            #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
            Backend::Epoll(e) => e.wait(events, timeout_ms),
            #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
            Backend::Unsupported => unsupported(),
        }
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
fn unsupported() -> io::Result<()> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "poller backend unavailable"))
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod imp {
    use super::Event;
    use std::arch::asm;
    use std::io;

    const SYS_CLOSE: u64 = 3;
    const SYS_EPOLL_WAIT: u64 = 232;
    const SYS_EPOLL_CTL: u64 = 233;
    const SYS_EPOLL_CREATE1: u64 = 291;

    pub(super) const EPOLL_CTL_ADD: i32 = 1;
    pub(super) const EPOLL_CTL_DEL: i32 = 2;
    pub(super) const EPOLL_CTL_MOD: i32 = 3;

    const EPOLLIN: u32 = 0x001;
    const EPOLLPRI: u32 = 0x002;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    /// Kernel ABI: on x86_64 `struct epoll_event` is packed to 12 bytes.
    /// Fields must be copied out by value — a reference into a packed
    /// struct is UB.
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    /// Four-argument raw syscall (epoll_wait and epoll_ctl both take
    /// four). The 4th argument travels in `r10`, not `rcx` — the
    /// `syscall` instruction clobbers `rcx` with the return address.
    ///
    /// # Safety
    ///
    /// The caller must pass argument values valid for `nr`'s ABI; the
    /// wrappers below only pass live fds and pointers to stack buffers.
    unsafe fn syscall4(nr: u64, a1: u64, a2: u64, a3: u64, a4: u64) -> i64 {
        let ret: i64;
        unsafe {
            asm!(
                "syscall",
                inlateout("rax") nr as i64 => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                in("r10") a4,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    fn check(ret: i64) -> io::Result<i64> {
        if ret < 0 {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    #[derive(Debug)]
    pub(super) struct Epoll {
        epfd: i32,
    }

    impl Epoll {
        pub(super) fn new() -> io::Result<Self> {
            // flags = 0: the fd lives for the thread's lifetime, no
            // CLOEXEC subtleties in a non-exec'ing harness.
            let ret = check(unsafe { syscall4(SYS_EPOLL_CREATE1, 0, 0, 0, 0) })?;
            Ok(Self { epfd: ret as i32 })
        }

        pub(super) fn ctl(&self, op: i32, fd: i32, token: u64, want_write: bool) -> io::Result<()> {
            let mut interest = EPOLLIN | EPOLLRDHUP;
            if want_write {
                interest |= EPOLLOUT;
            }
            let ev = EpollEvent { events: interest, data: token };
            // DEL ignores the event argument but older kernels want a
            // non-null pointer; passing it unconditionally is harmless.
            check(unsafe {
                syscall4(
                    SYS_EPOLL_CTL,
                    self.epfd as u64,
                    op as u64,
                    fd as u64,
                    &ev as *const EpollEvent as u64,
                )
            })?;
            Ok(())
        }

        pub(super) fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            let ret = unsafe {
                syscall4(
                    SYS_EPOLL_WAIT,
                    self.epfd as u64,
                    buf.as_mut_ptr() as u64,
                    buf.len() as u64,
                    timeout_ms as i64 as u64,
                )
            };
            let n = match check(ret) {
                Ok(n) => n as usize,
                // A signal interrupted the wait: report "no events" and
                // let the loop's next iteration pick work up.
                Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
                Err(e) => return Err(e),
            };
            for ev in buf.iter().take(n) {
                // Copy packed fields by value before touching them.
                let bits = { ev.events };
                let token = { ev.data };
                out.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLPRI) != 0,
                    writable: bits & EPOLLOUT != 0,
                    closed: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                syscall4(SYS_CLOSE, self.epfd as u64, 0, 0, 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    mod linux {
        use super::*;
        use std::io::Write;
        use std::net::{TcpListener, TcpStream};
        use std::os::fd::AsRawFd;

        #[test]
        fn create_register_wait_roundtrip() {
            let poller = Poller::new().expect("epoll_create1");
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut client = TcpStream::connect(addr).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();

            poller.add(server_side.as_raw_fd(), 7, false).expect("epoll_ctl ADD");

            // Nothing written yet: a short wait delivers no events.
            let mut events = Vec::new();
            poller.wait(&mut events, 0).expect("epoll_wait");
            assert!(events.iter().all(|e| e.token != 7 || !e.readable));

            client.write_all(b"ping").unwrap();
            client.flush().unwrap();

            // Readable now (allow a little scheduling slack).
            let mut seen = false;
            for _ in 0..50 {
                poller.wait(&mut events, 100).expect("epoll_wait");
                if events.iter().any(|e| e.token == 7 && e.readable) {
                    seen = true;
                    break;
                }
            }
            assert!(seen, "written bytes must surface as readability");

            // Write interest: a fresh socket is immediately writable.
            poller.modify(server_side.as_raw_fd(), 7, true).expect("epoll_ctl MOD");
            poller.wait(&mut events, 100).expect("epoll_wait");
            assert!(events.iter().any(|e| e.token == 7 && e.writable));

            poller.delete(server_side.as_raw_fd()).expect("epoll_ctl DEL");
            poller.wait(&mut events, 0).expect("epoll_wait");
            assert!(events.is_empty(), "deleted fd must not report events");
        }

        #[test]
        fn peer_close_reports_closed_or_readable() {
            let poller = Poller::new().unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let client = TcpStream::connect(addr).unwrap();
            let (server_side, _) = listener.accept().unwrap();
            server_side.set_nonblocking(true).unwrap();
            poller.add(server_side.as_raw_fd(), 1, false).unwrap();
            drop(client);

            let mut events = Vec::new();
            let mut seen = false;
            for _ in 0..50 {
                poller.wait(&mut events, 100).unwrap();
                if events.iter().any(|e| e.token == 1 && (e.closed || e.readable)) {
                    seen = true;
                    break;
                }
            }
            assert!(seen, "peer close must wake the poller");
            poller.delete(server_side.as_raw_fd()).unwrap();
        }

        #[test]
        fn invalid_fd_is_a_clean_error() {
            let poller = Poller::new().unwrap();
            // fd -1 is never valid: the kernel must answer EBADF, which
            // must surface as Err, not a panic or a success.
            assert!(poller.add(-1, 0, false).is_err());
            assert!(poller.delete(-1).is_err());
        }
    }

    #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
    #[test]
    fn unsupported_platform_fails_fast() {
        let err = Poller::new().expect_err("no backend on this platform");
        assert_eq!(err.kind(), io::ErrorKind::Unsupported);
    }
}
