//! Per-connection byte buffers: a compacting read accumulator and a
//! vectored write queue.
//!
//! [`ReadBuf`] holds bytes between socket reads so partial frames can
//! straddle reads: the codecs consume complete requests from the front
//! and leave incomplete tails for the next read. [`WriteQueue`] holds
//! queued response chunks and drains them with one `write_vectored`
//! (`writev`) call — each pipeline-fusion cycle produces a single chunk,
//! so a busy connection's responses go out in few syscalls.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};

/// How many bytes one `fill_from` call tries to read.
const READ_CHUNK: usize = 16 * 1024;

/// Compact when the consumed prefix passes this *and* dominates the
/// buffer (compaction is O(live bytes); do it when the copy is small
/// relative to the space reclaimed).
const COMPACT_THRESHOLD: usize = 64 * 1024;

/// Max IoSlices per `writev` (the kernel caps at IOV_MAX = 1024; 64 is
/// plenty — chunks are whole fusion cycles, not individual responses).
const MAX_IOVECS: usize = 64;

/// Read-side accumulator: bytes arrive at the tail, codecs consume from
/// the head, incomplete frames persist across socket reads.
#[derive(Debug, Default)]
pub struct ReadBuf {
    data: Vec<u8>,
    start: usize,
}

impl ReadBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// The unconsumed bytes (what the codecs parse).
    pub fn bytes(&self) -> &[u8] {
        &self.data[self.start..]
    }

    /// Number of unconsumed bytes.
    pub fn len(&self) -> usize {
        self.data.len() - self.start
    }

    /// Whether no unconsumed bytes remain.
    pub fn is_empty(&self) -> bool {
        self.start == self.data.len()
    }

    /// Mark `n` bytes as consumed. Compacts lazily once the dead prefix
    /// is both large and at least half the buffer.
    pub fn consume(&mut self, n: usize) {
        self.start += n;
        debug_assert!(self.start <= self.data.len());
        if self.start == self.data.len() {
            self.data.clear();
            self.start = 0;
        } else if self.start >= COMPACT_THRESHOLD && self.start >= self.data.len() / 2 {
            self.data.copy_within(self.start.., 0);
            self.data.truncate(self.data.len() - self.start);
            self.start = 0;
        }
    }

    /// Append bytes directly (tests and in-process feeding).
    pub fn push(&mut self, bytes: &[u8]) {
        self.data.extend_from_slice(bytes);
    }

    /// Read once from `r` into the tail. Returns the byte count (0 =
    /// EOF). `WouldBlock`/`Interrupted` are *not* errors here — they
    /// propagate so the caller can distinguish "drained" from EOF.
    pub fn fill_from<R: Read>(&mut self, r: &mut R) -> io::Result<usize> {
        let old = self.data.len();
        self.data.resize(old + READ_CHUNK, 0);
        match r.read(&mut self.data[old..]) {
            Ok(n) => {
                self.data.truncate(old + n);
                Ok(n)
            }
            Err(e) => {
                self.data.truncate(old);
                Err(e)
            }
        }
    }
}

/// Write-side queue: response chunks drain via vectored writes, with a
/// byte offset into the head chunk for partial-write resumption.
#[derive(Debug, Default)]
pub struct WriteQueue {
    chunks: VecDeque<Vec<u8>>,
    /// Bytes of the head chunk already written.
    head: usize,
    /// Total unwritten bytes across all chunks.
    queued: usize,
}

impl WriteQueue {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue a response chunk (empty chunks are dropped).
    pub fn push(&mut self, chunk: Vec<u8>) {
        if !chunk.is_empty() {
            self.queued += chunk.len();
            self.chunks.push_back(chunk);
        }
    }

    /// Unwritten bytes still queued.
    pub fn queued_bytes(&self) -> usize {
        self.queued
    }

    /// Whether everything queued has been written.
    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Drain as much as the socket accepts via `write_vectored`.
    /// Returns `Ok(true)` when the queue is fully drained, `Ok(false)`
    /// when the socket would block (register write interest and retry
    /// on writability). A zero-length write is an error (peer gone).
    pub fn flush<W: Write>(&mut self, w: &mut W) -> io::Result<bool> {
        self.flush_counted(w, &mut 0)
    }

    /// [`WriteQueue::flush`], also counting every `write_vectored`
    /// *call* (i.e. every attempted syscall, `WouldBlock` and
    /// `Interrupted` included) into `syscalls` — the readiness-mode
    /// feed for the server's `syscalls_per_op` accounting.
    pub fn flush_counted<W: Write>(&mut self, w: &mut W, syscalls: &mut u64) -> io::Result<bool> {
        while !self.is_empty() {
            let count = self.chunks.len().min(MAX_IOVECS);
            let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(count);
            for (i, chunk) in self.chunks.iter().take(MAX_IOVECS).enumerate() {
                let from = if i == 0 { self.head } else { 0 };
                slices.push(IoSlice::new(&chunk[from..]));
            }
            *syscalls += 1;
            match w.write_vectored(&slices) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => self.advance(n),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(true)
    }

    /// The unwritten slices, up to `max` of them, head-chunk offset
    /// applied — what a completion-mode backend points its gather-write
    /// at. The returned slices stay valid (and their storage unmoved)
    /// until the next [`WriteQueue::advance`]/`flush`/`push` on this
    /// queue mutates it.
    pub fn peek_slices(&self, max: usize) -> impl Iterator<Item = &[u8]> {
        let head = self.head;
        self.chunks.iter().take(max).enumerate().map(move |(i, chunk)| {
            let from = if i == 0 { head } else { 0 };
            &chunk[from..]
        })
    }

    /// Record `n` bytes as written by an external writer (a
    /// completion-mode backend's `writev` CQE); pops fully written
    /// chunks and moves the head offset into the next.
    pub fn advance(&mut self, mut n: usize) {
        debug_assert!(n <= self.queued);
        self.queued -= n;
        while n > 0 {
            let remaining = self.chunks[0].len() - self.head;
            if n >= remaining {
                n -= remaining;
                self.head = 0;
                self.chunks.pop_front();
            } else {
                self.head += n;
                n = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readbuf_accumulates_and_consumes() {
        let mut rb = ReadBuf::new();
        assert!(rb.is_empty());
        rb.push(b"hello ");
        rb.push(b"world");
        assert_eq!(rb.bytes(), b"hello world");
        rb.consume(6);
        assert_eq!(rb.bytes(), b"world");
        assert_eq!(rb.len(), 5);
        rb.consume(5);
        assert!(rb.is_empty());
        // A full consume resets the backing storage.
        rb.push(b"x");
        assert_eq!(rb.bytes(), b"x");
    }

    #[test]
    fn readbuf_compacts_without_losing_bytes() {
        let mut rb = ReadBuf::new();
        // Push well past the compaction threshold, consume most of it in
        // steps, and verify the tail stays intact throughout.
        let big: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        rb.push(&big);
        rb.consume(150_000);
        assert_eq!(rb.bytes(), &big[150_000..]);
        rb.consume(1);
        assert_eq!(rb.bytes(), &big[150_001..]);
    }

    #[test]
    fn readbuf_fill_from_reader() {
        let mut rb = ReadBuf::new();
        let mut src: &[u8] = b"abc";
        assert_eq!(rb.fill_from(&mut src).unwrap(), 3);
        assert_eq!(rb.bytes(), b"abc");
        // Source exhausted: EOF is Ok(0), buffer unchanged.
        assert_eq!(rb.fill_from(&mut src).unwrap(), 0);
        assert_eq!(rb.bytes(), b"abc");
    }

    /// A writer that accepts at most `cap` bytes per call — exercises
    /// partial-write resumption across chunk boundaries.
    struct Dribble {
        out: Vec<u8>,
        cap: usize,
    }

    impl Write for Dribble {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            let n = buf.len().min(self.cap);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn writequeue_drains_across_partial_writes() {
        let mut wq = WriteQueue::new();
        wq.push(b"END\r\n".to_vec());
        wq.push(Vec::new()); // dropped
        wq.push(b"STORED\r\n".to_vec());
        wq.push(b"VALUE k 0 1\r\n7\r\nEND\r\n".to_vec());
        assert_eq!(wq.queued_bytes(), 5 + 8 + 22);

        let mut w = Dribble { out: Vec::new(), cap: 3 };
        assert!(wq.flush(&mut w).unwrap());
        assert!(wq.is_empty());
        assert_eq!(w.out, b"END\r\nSTORED\r\nVALUE k 0 1\r\n7\r\nEND\r\n");
    }

    struct Blocky {
        accepted: usize,
    }

    impl Write for Blocky {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.accepted == 0 {
                Err(io::Error::new(io::ErrorKind::WouldBlock, "full"))
            } else {
                let n = buf.len().min(self.accepted);
                self.accepted -= n;
                Ok(n)
            }
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn writequeue_reports_wouldblock_and_resumes() {
        let mut wq = WriteQueue::new();
        wq.push(b"0123456789".to_vec());
        let mut w = Blocky { accepted: 4 };
        assert!(!wq.flush(&mut w).unwrap(), "partial drain must report not-done");
        assert_eq!(wq.queued_bytes(), 6);
        let mut w2 = Dribble { out: Vec::new(), cap: 100 };
        assert!(wq.flush(&mut w2).unwrap());
        assert_eq!(w2.out, b"456789");
    }

    #[test]
    fn peek_slices_and_external_advance() {
        let mut wq = WriteQueue::new();
        wq.push(b"abcde".to_vec());
        wq.push(b"fg".to_vec());
        let slices: Vec<&[u8]> = wq.peek_slices(8).collect();
        assert_eq!(slices, vec![&b"abcde"[..], &b"fg"[..]]);
        // A completion-mode writer reports progress via advance; the
        // head chunk's written prefix must drop out of the next peek.
        wq.advance(3);
        let slices: Vec<&[u8]> = wq.peek_slices(8).collect();
        assert_eq!(slices, vec![&b"de"[..], &b"fg"[..]]);
        assert_eq!(wq.queued_bytes(), 4);
        // `max` caps the iovec count without losing later chunks.
        assert_eq!(wq.peek_slices(1).count(), 1);
        wq.advance(4);
        assert!(wq.is_empty());
        assert_eq!(wq.peek_slices(8).count(), 0);
    }

    #[test]
    fn flush_counted_counts_attempted_syscalls() {
        let mut wq = WriteQueue::new();
        wq.push(b"0123456789".to_vec());
        let mut syscalls = 0u64;
        // 3-byte dribble: 10 bytes take 4 write_vectored calls.
        let mut w = Dribble { out: Vec::new(), cap: 3 };
        assert!(wq.flush_counted(&mut w, &mut syscalls).unwrap());
        assert_eq!(syscalls, 4);
        // A WouldBlock answer still cost a syscall.
        wq.push(b"xy".to_vec());
        let mut blocked = Blocky { accepted: 0 };
        assert!(!wq.flush_counted(&mut blocked, &mut syscalls).unwrap());
        assert_eq!(syscalls, 5);
    }

    #[test]
    fn writequeue_zero_write_is_an_error() {
        struct Zero;
        impl Write for Zero {
            fn write(&mut self, _buf: &[u8]) -> io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let mut wq = WriteQueue::new();
        wq.push(b"x".to_vec());
        assert!(wq.flush(&mut Zero).is_err());
    }
}
