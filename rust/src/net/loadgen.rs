//! Pipelined TCP load generator (`kway loadgen`).
//!
//! Drives a running `kway serve` endpoint over either wire protocol
//! with `--connections C × --pipeline P × --threads T`: each thread
//! owns its share of the connections, writes P requests per connection
//! per round (one `write_all`, so the server sees a genuine pipeline),
//! then collects the P responses — send-all-then-read-all across the
//! thread's connections keeps every pipeline in flight while earlier
//! ones are being read. Keys reuse the synthetic workload machinery
//! (uniform or Zipf over `--keyspace`, the harness's `Rng`/`Zipf`),
//! a `1/set_every` fraction of requests are stores (optionally with
//! `--ttl`, exercising the expiry path over the wire), and `--pin`
//! pins generator threads to cores like the in-process harness.
//!
//! `--value-dist` picks the store payloads: `word` (decimal `key+1`,
//! the pre-slab default) or a byte distribution (`fixed:N`,
//! `uniform:MAX`, `zipf:MAX` — [`crate::lifetime::ValueDist`]), whose
//! deterministic key-stamped blobs drive a byte-value server. Response
//! reads are length-driven either way — the memcached `VALUE` header's
//! byte count and the RESP `$len` prefix frame the data block, which is
//! never scanned for CRLF — so binary payloads round-trip cleanly.
//!
//! Latency: the round-trip of each P-deep pipeline is measured and
//! recorded as P amortized per-op samples in a per-thread
//! [`Reservoir`] (10K samples, Snippet 3 methodology), so reported
//! p50/p99 are per-op figures comparable across pipeline depths.
//!
//! The generator is blocking `std::net` on purpose: it needs C
//! concurrent pipelines, not an event loop, and portable clients keep
//! the smoke test runnable where the epoll server itself cannot run.

use crate::fault::FaultPlan;
use crate::lifetime::ValueDist;
use crate::util::affinity;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::{percentile_u64, Reservoir};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-thread reservoir capacity (SNIPPETS.md Snippet 3: 10K per
/// thread is plenty for stable p50/p95/p99).
const RESERVOIR_CAP: usize = 10_000;

/// Which wire protocol to speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireProto {
    /// Memcached text protocol.
    Memcached,
    /// RESP arrays-of-bulk-strings.
    Resp,
}

impl WireProto {
    /// Parse a `--proto` argument.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "memcached" | "mc" => Some(Self::Memcached),
            "resp" | "redis" => Some(Self::Resp),
            _ => None,
        }
    }

    /// Canonical name (JSON rows, report lines).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Memcached => "memcached",
            Self::Resp => "resp",
        }
    }
}

/// Load-generator configuration (CLI defaults live in `main.rs`).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:11211`.
    pub addr: String,
    /// Wire protocol to speak.
    pub proto: WireProto,
    /// Total client connections, dealt round-robin to threads.
    pub connections: usize,
    /// Requests per pipeline round per connection.
    pub pipeline: usize,
    /// Generator threads.
    pub threads: usize,
    /// How long to drive load.
    pub duration: Duration,
    /// Keys are drawn from `0..keyspace`.
    pub keyspace: u64,
    /// Every `set_every`-th request is a store (0 = read-only).
    pub set_every: u64,
    /// TTL attached to stores (`exptime`/`EX`/`PX`); `None` = immortal.
    pub ttl: Option<Duration>,
    /// Store payload distribution: decimal words (default) or
    /// deterministic key-stamped byte blobs.
    pub value_dist: ValueDist,
    /// Zipf skew for key sampling; `None` = uniform.
    pub zipf_alpha: Option<f64>,
    /// RNG seed (thread t forks seed + t).
    pub seed: u64,
    /// Pin generator threads to cores.
    pub pin: bool,
    /// Per-thread budget of reconnect attempts. A mid-run io error
    /// (reset, timeout, server restart) counts into `errors` and the
    /// connection is re-dialed with jittered exponential backoff; only
    /// an exhausted budget fails the run. `0` restores the historical
    /// fail-fast behaviour.
    pub max_reconnects: u64,
    /// Fault plan for the client-side injection point (`conn_drop`);
    /// inert unless armed.
    pub faults: Option<Arc<FaultPlan>>,
}

impl LoadgenConfig {
    /// The CI smoke preset: small, fast, deterministic — two
    /// connections, a real pipeline, a keyspace that warms quickly.
    pub fn smoke(addr: &str, proto: WireProto) -> Self {
        Self {
            addr: addr.to_string(),
            proto,
            connections: 2,
            pipeline: 8,
            threads: 1,
            duration: Duration::from_millis(300),
            keyspace: 512,
            set_every: 4,
            ttl: None,
            value_dist: ValueDist::Word,
            zipf_alpha: None,
            seed: 42,
            pin: false,
            max_reconnects: 64,
            faults: None,
        }
    }
}

/// Aggregated outcome of one loadgen run.
#[derive(Debug, Clone, Default)]
pub struct LoadgenResult {
    /// Requests sent (gets + sets).
    pub ops: u64,
    /// Read requests.
    pub gets: u64,
    /// Read requests answered with a value.
    pub hits: u64,
    /// Store requests.
    pub sets: u64,
    /// Error responses (protocol errors, unexpected replies) plus
    /// mid-run connection failures that forced a reconnect.
    pub errors: u64,
    /// Connections re-dialed mid-run (after an io error or an injected
    /// `conn_drop`).
    pub reconnects: u64,
    /// Wall-clock seconds of the drive phase.
    pub secs: f64,
    /// Amortized per-op latency, 50th percentile (ns).
    pub p50_ns: u64,
    /// Amortized per-op latency, 99th percentile (ns).
    pub p99_ns: u64,
    /// Amortized per-op latency, mean (ns).
    pub mean_ns: f64,
}

impl LoadgenResult {
    /// Million requests per second.
    pub fn mops(&self) -> f64 {
        if self.secs > 0.0 {
            self.ops as f64 / self.secs / 1e6
        } else {
            0.0
        }
    }

    /// Hit ratio over read requests (0 when nothing was read).
    pub fn hit_ratio(&self) -> f64 {
        if self.gets > 0 {
            self.hits as f64 / self.gets as f64
        } else {
            0.0
        }
    }
}

/// Drive `cfg.addr` and aggregate counters + latency percentiles.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenResult> {
    if cfg.connections == 0 || cfg.pipeline == 0 || cfg.threads == 0 {
        bail!("connections, pipeline, and threads must all be >= 1");
    }
    let threads = cfg.threads.min(cfg.connections);
    let started = Instant::now();
    let mut merged = LoadgenResult::default();
    let mut samples: Vec<u64> = Vec::new();

    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            handles.push(scope.spawn(move || worker(cfg, t, threads)));
        }
        for h in handles {
            let (stats, reservoir) = h.join().expect("loadgen thread panicked")?;
            merged.ops += stats.ops;
            merged.gets += stats.gets;
            merged.hits += stats.hits;
            merged.sets += stats.sets;
            merged.errors += stats.errors;
            merged.reconnects += stats.reconnects;
            samples.extend_from_slice(reservoir.samples());
        }
        Ok(())
    })?;

    merged.secs = started.elapsed().as_secs_f64();
    if !samples.is_empty() {
        merged.mean_ns = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
        merged.p50_ns = percentile_u64(&mut samples, 50.0);
        merged.p99_ns = percentile_u64(&mut samples, 99.0);
    }
    Ok(merged)
}

#[derive(Debug, Default)]
struct ThreadStats {
    ops: u64,
    gets: u64,
    hits: u64,
    sets: u64,
    errors: u64,
    reconnects: u64,
}

#[derive(Debug, Clone, Copy)]
enum ReqKind {
    Get,
    Set,
}

struct ClientConn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Request kinds of the in-flight round, for response parsing.
    kinds: Vec<ReqKind>,
    /// Reusable request build buffer.
    wire: Vec<u8>,
}

fn worker(
    cfg: &LoadgenConfig,
    thread_id: usize,
    threads: usize,
) -> Result<(ThreadStats, Reservoir)> {
    if cfg.pin {
        affinity::pin_to_core(thread_id);
    }
    // Connections dealt round-robin: thread t owns conns t, t+T, ...
    let mut conns = Vec::new();
    for c in (thread_id..cfg.connections).step_by(threads) {
        conns.push(connect(cfg).with_context(|| format!("connecting conn {c}"))?);
    }

    let thread_seed = cfg.seed.wrapping_add(thread_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut rng = Rng::new(thread_seed);
    let zipf = cfg.zipf_alpha.map(|a| Zipf::new(cfg.keyspace.max(1), a));
    let mut stats = ThreadStats::default();
    let mut reservoir = Reservoir::new(RESERVOIR_CAP, cfg.seed.wrapping_add(thread_id as u64));
    let mut payload: Vec<u8> = Vec::new();
    let mut req_counter: u64 = 0;
    let deadline = Instant::now() + cfg.duration;

    let plan = cfg.faults.as_deref();

    while Instant::now() < deadline {
        // Send phase: queue a full pipeline on every connection. An io
        // error mid-run is a counted, survivable event — re-dial and
        // carry on — not a run-fatal one (ISSUE 8: the torture test
        // kills connections on purpose).
        for conn in conns.iter_mut() {
            // Injected flaky client: drop the connection (the server
            // sees an abrupt close mid-stream) and re-dial.
            if plan.is_some_and(|p| p.should_drop_conn(&mut rng)) {
                *conn = reconnect(cfg, &mut rng, &mut stats)?;
            }
            conn.wire.clear();
            conn.kinds.clear();
            for _ in 0..cfg.pipeline {
                let key = match &zipf {
                    Some(z) => z.sample(&mut rng),
                    None => rng.below(cfg.keyspace.max(1)),
                };
                let is_set = cfg.set_every > 0 && req_counter % cfg.set_every == 0;
                req_counter += 1;
                if is_set {
                    encode_set(cfg, &mut conn.wire, &mut payload, key, key + 1);
                    conn.kinds.push(ReqKind::Set);
                } else {
                    encode_get(cfg, &mut conn.wire, key);
                    conn.kinds.push(ReqKind::Get);
                }
            }
            if conn.stream.write_all(&conn.wire).is_err() {
                stats.errors += 1;
                conn.kinds.clear(); // nothing reached the server whole
                *conn = reconnect(cfg, &mut rng, &mut stats)?;
            }
        }

        // Read phase: collect every connection's responses; record the
        // pipeline round-trip as amortized per-op samples. A read error
        // abandons the round's remaining responses (the replacement
        // connection has no history to collect).
        for conn in conns.iter_mut() {
            if conn.kinds.is_empty() {
                continue; // send failed: nothing in flight
            }
            let round_start = Instant::now();
            let mut failed = false;
            for i in 0..conn.kinds.len() {
                let result = match conn.kinds[i] {
                    ReqKind::Get => read_get_response(cfg, conn, &mut stats),
                    ReqKind::Set => read_set_response(cfg, conn, &mut stats),
                };
                if result.is_err() {
                    stats.errors += 1;
                    failed = true;
                    break;
                }
            }
            if failed {
                conn.kinds.clear();
                *conn = reconnect(cfg, &mut rng, &mut stats)?;
                continue;
            }
            let per_op = round_start.elapsed().as_nanos() as u64 / cfg.pipeline as u64;
            for _ in 0..cfg.pipeline {
                reservoir.record(per_op);
            }
            stats.ops += conn.kinds.len() as u64;
        }
    }
    Ok((stats, reservoir))
}

/// Dial `addr` and fetch the server's `stats` pairs (memcached text
/// `STAT name value` lines until `END`). Used by `kway loadgen --json`
/// to snapshot the server-side syscall ledger around a run, so the
/// bench rows can report a measured `syscalls_per_op` and the serving
/// backend instead of client-side guesses.
pub fn fetch_stats(addr: &str) -> Result<Vec<(String, String)>> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10))).context("setting read timeout")?;
    stream.write_all(b"stats\r\n").context("sending stats")?;
    let mut reader = BufReader::new(stream);
    let mut pairs = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).context("reading stats line")? == 0 {
            bail!("connection closed mid-stats");
        }
        let line = line.trim_end();
        if line == "END" {
            return Ok(pairs);
        }
        match line.strip_prefix("STAT ").and_then(|r| r.split_once(' ')) {
            Some((name, value)) => pairs.push((name.to_string(), value.to_string())),
            None => bail!("unexpected stats line {line:?}"),
        }
    }
}

/// Dial one client connection.
fn connect(cfg: &LoadgenConfig) -> Result<ClientConn> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting to {}", cfg.addr))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10))).context("setting read timeout")?;
    let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    Ok(ClientConn { stream, reader, kinds: Vec::new(), wire: Vec::new() })
}

/// Re-dial after a drop or io error, with jittered exponential backoff
/// between failed attempts. Fails only when the per-thread
/// `max_reconnects` budget is exhausted.
fn reconnect(cfg: &LoadgenConfig, rng: &mut Rng, stats: &mut ThreadStats) -> Result<ClientConn> {
    let mut backoff = Duration::from_millis(1);
    loop {
        if stats.reconnects >= cfg.max_reconnects {
            bail!("reconnect budget exhausted ({}) against {}", cfg.max_reconnects, cfg.addr);
        }
        stats.reconnects += 1;
        match connect(cfg) {
            Ok(conn) => return Ok(conn),
            Err(e) => {
                if stats.reconnects >= cfg.max_reconnects {
                    return Err(e).context("last reconnect attempt failed");
                }
                // Jitter de-synchronizes threads hammering a reviving
                // server; the cap keeps the generator responsive.
                std::thread::sleep(backoff + Duration::from_micros(rng.below(500)));
                backoff = (backoff * 2).min(Duration::from_millis(100));
            }
        }
    }
}

fn encode_get(cfg: &LoadgenConfig, wire: &mut Vec<u8>, key: u64) {
    match cfg.proto {
        WireProto::Memcached => {
            wire.extend_from_slice(b"get ");
            wire.extend_from_slice(key.to_string().as_bytes());
            wire.extend_from_slice(b"\r\n");
        }
        WireProto::Resp => {
            let k = key.to_string();
            wire.extend_from_slice(
                format!("*2\r\n$3\r\nGET\r\n${}\r\n{}\r\n", k.len(), k).as_bytes(),
            );
        }
    }
}

fn encode_set(cfg: &LoadgenConfig, wire: &mut Vec<u8>, payload: &mut Vec<u8>, key: u64, value: u64) {
    // Payload: the word path sends decimal `key+1` (so hits are
    // verifiable); byte distributions send deterministic key-stamped
    // blobs ([`ValueDist::fill`]) that may contain CRLF/NUL — the
    // framing below is length-prefixed either way.
    if cfg.value_dist.is_bytes() {
        cfg.value_dist.fill(key, payload);
    } else {
        payload.clear();
        payload.extend_from_slice(value.to_string().as_bytes());
    }
    let k = key.to_string();
    match cfg.proto {
        WireProto::Memcached => {
            // exptime is relative seconds; sub-second TTLs round up so a
            // TTL'd smoke run still exercises the expiry path.
            let exptime = cfg.ttl.map(|t| t.as_secs().max(1)).unwrap_or(0);
            wire.extend_from_slice(
                format!("set {k} 0 {exptime} {}\r\n", payload.len()).as_bytes(),
            );
            wire.extend_from_slice(payload);
            wire.extend_from_slice(b"\r\n");
        }
        WireProto::Resp => {
            let argc = if cfg.ttl.is_some() { 5 } else { 3 };
            wire.extend_from_slice(
                format!("*{argc}\r\n$3\r\nSET\r\n${}\r\n{k}\r\n${}\r\n", k.len(), payload.len())
                    .as_bytes(),
            );
            wire.extend_from_slice(payload);
            wire.extend_from_slice(b"\r\n");
            if let Some(t) = cfg.ttl {
                let ms = t.as_millis().max(1).to_string();
                wire.extend_from_slice(format!("$2\r\nPX\r\n${}\r\n{ms}\r\n", ms.len()).as_bytes());
            }
        }
    }
}

fn read_line(conn: &mut ClientConn) -> Result<String> {
    let mut line = String::new();
    let n = conn.reader.read_line(&mut line).context("reading response line")?;
    if n == 0 {
        bail!("server closed the connection mid-response");
    }
    Ok(line.trim_end().to_string())
}

/// Consume a length-framed data block plus its trailing CRLF. Binary-
/// safe by construction: `len` rules, the block is never line-scanned.
fn read_data_block(conn: &mut ClientConn, len: usize) -> Result<()> {
    let mut buf = vec![0u8; len + 2];
    conn.reader.read_exact(&mut buf).context("reading data block")?;
    if &buf[len..] != b"\r\n" {
        bail!("data block not terminated by CRLF");
    }
    Ok(())
}

fn read_get_response(
    cfg: &LoadgenConfig,
    conn: &mut ClientConn,
    stats: &mut ThreadStats,
) -> Result<()> {
    stats.gets += 1;
    match cfg.proto {
        WireProto::Memcached => loop {
            let line = read_line(conn)?;
            if line == "END" {
                return Ok(());
            } else if let Some(rest) = line.strip_prefix("VALUE ") {
                stats.hits += 1;
                // VALUE <key> <flags> <len> [<cas>]: the byte count
                // frames the data block.
                let len: usize = rest
                    .split_ascii_whitespace()
                    .nth(2)
                    .and_then(|t| t.parse().ok())
                    .context("unparseable VALUE header length")?;
                read_data_block(conn, len)?;
            } else {
                // ERROR / CLIENT_ERROR / SERVER_ERROR: no END follows.
                stats.errors += 1;
                return Ok(());
            }
        },
        WireProto::Resp => {
            let line = read_line(conn)?;
            if line == "$-1" {
                Ok(())
            } else if let Some(lenstr) = line.strip_prefix('$') {
                stats.hits += 1;
                let len: usize =
                    lenstr.parse().context("unparseable RESP bulk length")?;
                read_data_block(conn, len)?;
                Ok(())
            } else {
                stats.errors += 1;
                Ok(())
            }
        }
    }
}

fn read_set_response(
    cfg: &LoadgenConfig,
    conn: &mut ClientConn,
    stats: &mut ThreadStats,
) -> Result<()> {
    stats.sets += 1;
    let line = read_line(conn)?;
    let ok = match cfg.proto {
        WireProto::Memcached => line == "STORED",
        WireProto::Resp => line == "+OK",
    };
    if !ok {
        stats.errors += 1;
    }
    Ok(())
}
