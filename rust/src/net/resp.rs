//! RESP (redis serialization protocol) codec — the arrays-of-bulk-
//! strings request subset.
//!
//! Supported commands (case-insensitive): `PING`, `GET`, `SET key value
//! [EX seconds | PX milliseconds]`, `MGET`, `MSET`, `DEL` (multi-key,
//! replies the removed count), `EXPIRE key seconds` (replies `:1`/`:0`),
//! `QUIT`. Everything else answers `-ERR unknown command`.
//!
//! Requests must be RESP arrays of bulk strings (`*n` then `$len` pairs)
//! — the inline-command form is not accepted; a connection whose first
//! byte is not `*` is handled as memcached text by the protocol sniffer
//! in [`super::conn`]. The parser is stateless: an incomplete frame
//! consumes nothing and is retried when more bytes arrive; structurally
//! corrupt framing (non-`*` start, bad length digits, missing CRLF,
//! oversized counts) is fatal because the stream cannot be re-framed.
//!
//! Values are **binary-safe** end to end: bulk strings are length-
//! prefixed by construction, so `SET`/`MSET` payloads ride through as
//! raw bytes (up to [`MAX_VALUE_LEN`]) and `GET`/`MGET` replies are
//! emitted as raw bulks — whether a payload is storable is decided at
//! execution (word caches still require ASCII-decimal `u64`).

use super::{parse_value, Command, FatalProtocolError, WireKey, MAX_KEY_LEN, MAX_VALUE_LEN};

/// Max elements in one request array (MSET of 512 pairs fits).
const MAX_ARRAY: usize = 1024;

/// Stateless RESP request decoder (struct for codec-API symmetry).
#[derive(Debug, Default)]
pub struct RespDecoder;

impl RespDecoder {
    /// A fresh decoder.
    pub fn new() -> Self {
        Self
    }

    /// Try to decode one request array from the front of `buf`.
    /// `Ok(None)` = incomplete (consume nothing); `Err` = framing lost.
    pub fn decode(&mut self, buf: &[u8]) -> Result<Option<(Command, usize)>, FatalProtocolError> {
        let Some((args, consumed)) = parse_array(buf)? else {
            return Ok(None);
        };
        Ok(Some((interpret(&args), consumed)))
    }
}

/// Parse `*n\r\n` followed by `n` bulk strings. Returns the argument
/// vector and the total bytes consumed, or `None` if incomplete.
fn parse_array(buf: &[u8]) -> Result<Option<(Vec<Vec<u8>>, usize)>, FatalProtocolError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf[0] != b'*' {
        return Err(FatalProtocolError(format!(
            "expected '*' to open a RESP array, got byte {:#04x}",
            buf[0]
        )));
    }
    let Some((count, mut pos)) = parse_length(&buf[1..], 1)? else {
        return Ok(None);
    };
    if count == 0 || count > MAX_ARRAY {
        return Err(FatalProtocolError(format!(
            "RESP array of {count} elements outside 1..={MAX_ARRAY}"
        )));
    }
    let mut args = Vec::with_capacity(count);
    for _ in 0..count {
        if pos >= buf.len() {
            return Ok(None);
        }
        if buf[pos] != b'$' {
            return Err(FatalProtocolError(format!(
                "expected '$' bulk string, got byte {:#04x}",
                buf[pos]
            )));
        }
        let Some((len, data_start)) = parse_length(&buf[pos + 1..], pos + 1)? else {
            return Ok(None);
        };
        if len > MAX_VALUE_LEN.max(MAX_KEY_LEN) {
            return Err(FatalProtocolError(format!("bulk string of {len} bytes exceeds caps")));
        }
        let data_end = data_start + len;
        if buf.len() < data_end + 2 {
            return Ok(None);
        }
        if &buf[data_end..data_end + 2] != b"\r\n" {
            return Err(FatalProtocolError("bulk string not terminated by CRLF".into()));
        }
        args.push(buf[data_start..data_end].to_vec());
        pos = data_end + 2;
    }
    Ok(Some((args, pos)))
}

/// Parse a decimal length followed by CRLF starting at `buf[0]`;
/// `base` is the absolute offset of `buf[0]` in the original frame.
/// Returns `(length, absolute offset past the CRLF)`.
fn parse_length(
    buf: &[u8],
    base: usize,
) -> Result<Option<(usize, usize)>, FatalProtocolError> {
    // Longest sane length is 7 digits (caps are ≤ MAX_VALUE_LEN); a
    // digit run past that is corrupt, not incomplete.
    const MAX_DIGITS: usize = 7;
    let mut n: usize = 0;
    let mut i = 0;
    while i < buf.len() && buf[i].is_ascii_digit() {
        if i >= MAX_DIGITS {
            return Err(FatalProtocolError("unreasonably long RESP length field".into()));
        }
        n = n * 10 + (buf[i] - b'0') as usize;
        i += 1;
    }
    if i == 0 && !buf.is_empty() {
        return Err(FatalProtocolError(format!(
            "RESP length must start with a digit, got byte {:#04x}",
            buf[0]
        )));
    }
    // Need the CRLF after the digits.
    if buf.len() < i + 2 {
        return Ok(None);
    }
    if &buf[i..i + 2] != b"\r\n" {
        return Err(FatalProtocolError("RESP length not terminated by CRLF".into()));
    }
    Ok(Some((n, base + i + 2)))
}

/// Map a parsed argument vector onto the shared [`Command`] enum.
fn interpret(args: &[Vec<u8>]) -> Command {
    let verb = args[0].to_ascii_uppercase();
    match verb.as_slice() {
        b"PING" => Command::Ping,
        b"QUIT" => Command::Quit,
        b"INFO" => Command::Stats,
        b"GET" => match args {
            [_, key] => match wire_key(key) {
                Ok(k) => Command::Read { keys: vec![k], cas: false, single: true },
                Err(e) => e,
            },
            _ => err("wrong number of arguments for 'GET'"),
        },
        b"MGET" => {
            if args.len() < 2 {
                return err("wrong number of arguments for 'MGET'");
            }
            let mut keys = Vec::with_capacity(args.len() - 1);
            for raw in &args[1..] {
                match wire_key(raw) {
                    Ok(k) => keys.push(k),
                    Err(e) => return e,
                }
            }
            Command::Read { keys, cas: false, single: false }
        }
        b"SET" => interpret_set(args),
        b"MSET" => {
            if args.len() < 3 || args.len() % 2 == 0 {
                return err("wrong number of arguments for 'MSET'");
            }
            let mut items = Vec::with_capacity(args.len() / 2);
            for pair in args[1..].chunks_exact(2) {
                let key = match wire_key(&pair[0]) {
                    Ok(k) => k,
                    Err(e) => return e,
                };
                items.push((key, pair[1].clone()));
            }
            Command::WriteMany { items }
        }
        b"DEL" => {
            if args.len() < 2 {
                return err("wrong number of arguments for 'DEL'");
            }
            let mut keys = Vec::with_capacity(args.len() - 1);
            for raw in &args[1..] {
                match wire_key(raw) {
                    Ok(k) => keys.push(k),
                    Err(e) => return e,
                }
            }
            Command::Delete { keys, noreply: false }
        }
        b"EXPIRE" => match args {
            [_, key, secs] => {
                let k = match wire_key(key) {
                    Ok(k) => k,
                    Err(e) => return e,
                };
                let Some(s) = parse_value(secs) else {
                    return err("value is not an integer or out of range");
                };
                Command::Touch {
                    key: k,
                    ttl: Some(std::time::Duration::from_secs(s)),
                    noreply: false,
                }
            }
            _ => err("wrong number of arguments for 'EXPIRE'"),
        },
        _ => err("unknown command"),
    }
}

fn interpret_set(args: &[Vec<u8>]) -> Command {
    // SET key value [EX seconds | PX milliseconds]
    let (key_raw, value_raw, ttl_args) = match args {
        [_, k, v] => (k, v, &args[3..]),
        [_, k, v, _, _] => (k, v, &args[3..]),
        _ => return err("wrong number of arguments for 'SET'"),
    };
    let key = match wire_key(key_raw) {
        Ok(k) => k,
        Err(e) => return e,
    };
    let ttl = match ttl_args {
        [] => None,
        [unit, amount] => {
            let Some(n) = parse_value(amount) else {
                return err("value is not an integer or out of range");
            };
            match unit.to_ascii_uppercase().as_slice() {
                b"EX" => Some(std::time::Duration::from_secs(n)),
                b"PX" => Some(std::time::Duration::from_millis(n)),
                _ => return err("syntax error"),
            }
        }
        _ => return err("syntax error"),
    };
    Command::Write { key, value: value_raw.clone(), ttl, add_only: false, noreply: false }
}

fn wire_key(raw: &[u8]) -> Result<WireKey, Command> {
    if raw.len() > MAX_KEY_LEN {
        return Err(err("key too long"));
    }
    Ok(WireKey::from_bytes(raw))
}

fn err(msg: &str) -> Command {
    Command::Bad { line: format!("-ERR {msg}") }
}

/// Append `+OK`.
pub fn encode_ok(out: &mut Vec<u8>) {
    out.extend_from_slice(b"+OK\r\n");
}

/// Append `+PONG`.
pub fn encode_pong(out: &mut Vec<u8>) {
    out.extend_from_slice(b"+PONG\r\n");
}

/// Append an integer reply `:n`.
pub fn encode_int(out: &mut Vec<u8>, n: i64) {
    out.push(b':');
    out.extend_from_slice(n.to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
}

/// Append a bulk-string reply: the value's decimal text, or the null
/// bulk `$-1` for a miss.
pub fn encode_bulk(out: &mut Vec<u8>, value: Option<u64>) {
    match value {
        None => out.extend_from_slice(b"$-1\r\n"),
        Some(v) => {
            let body = v.to_string();
            out.push(b'$');
            out.extend_from_slice(body.len().to_string().as_bytes());
            out.extend_from_slice(b"\r\n");
            out.extend_from_slice(body.as_bytes());
            out.extend_from_slice(b"\r\n");
        }
    }
}

/// Append a bulk-string reply carrying raw bytes (a byte-value `GET`
/// hit), or the null bulk `$-1` for a miss. Binary-safe: the length
/// prefix frames the payload, CRLF/NUL inside it are fine.
pub fn encode_bulk_bytes(out: &mut Vec<u8>, value: Option<&[u8]>) {
    match value {
        None => out.extend_from_slice(b"$-1\r\n"),
        Some(v) => {
            out.push(b'$');
            out.extend_from_slice(v.len().to_string().as_bytes());
            out.extend_from_slice(b"\r\n");
            out.extend_from_slice(v);
            out.extend_from_slice(b"\r\n");
        }
    }
}

/// Append a bulk-string reply carrying arbitrary text (the `INFO`
/// response body).
pub fn encode_bulk_str(out: &mut Vec<u8>, body: &str) {
    out.push(b'$');
    out.extend_from_slice(body.len().to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body.as_bytes());
    out.extend_from_slice(b"\r\n");
}

/// Append an array header `*n` (elements follow as bulk replies).
pub fn encode_array_header(out: &mut Vec<u8>, n: usize) {
    out.push(b'*');
    out.extend_from_slice(n.to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
}

/// Append an error line (caller supplies the leading `-`).
pub fn encode_error(out: &mut Vec<u8>, line: &str) {
    out.extend_from_slice(line.as_bytes());
    out.extend_from_slice(b"\r\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn frame(parts: &[&[u8]]) -> Vec<u8> {
        let mut f = format!("*{}\r\n", parts.len()).into_bytes();
        for p in parts {
            f.extend_from_slice(format!("${}\r\n", p.len()).as_bytes());
            f.extend_from_slice(p);
            f.extend_from_slice(b"\r\n");
        }
        f
    }

    fn one(wire: &[u8]) -> Command {
        let mut dec = RespDecoder::new();
        let (cmd, n) = dec.decode(wire).expect("no fatal").expect("complete");
        assert_eq!(n, wire.len(), "must consume the whole frame");
        cmd
    }

    #[test]
    fn ping_get_set_parse() {
        assert_eq!(one(&frame(&[b"PING"])), Command::Ping);
        assert_eq!(one(&frame(&[b"ping"])), Command::Ping, "case-insensitive");
        assert_eq!(
            one(&frame(&[b"GET", b"42"])),
            Command::Read { keys: vec![WireKey::from_bytes(b"42")], cas: false, single: true }
        );
        assert_eq!(
            one(&frame(&[b"SET", b"42", b"7"])),
            Command::Write {
                key: WireKey::from_bytes(b"42"),
                value: b"7".to_vec(),
                ttl: None,
                add_only: false,
                noreply: false,
            }
        );
    }

    #[test]
    fn set_with_ex_and_px() {
        assert_eq!(
            one(&frame(&[b"SET", b"1", b"2", b"EX", b"30"])),
            Command::Write {
                key: WireKey::from_bytes(b"1"),
                value: b"2".to_vec(),
                ttl: Some(Duration::from_secs(30)),
                add_only: false,
                noreply: false,
            }
        );
        assert_eq!(
            one(&frame(&[b"SET", b"1", b"2", b"px", b"1500"])),
            Command::Write {
                key: WireKey::from_bytes(b"1"),
                value: b"2".to_vec(),
                ttl: Some(Duration::from_millis(1500)),
                add_only: false,
                noreply: false,
            }
        );
        assert!(matches!(
            one(&frame(&[b"SET", b"1", b"2", b"XX", b"5"])),
            Command::Bad { .. }
        ));
    }

    #[test]
    fn mget_mset_del_expire_parse() {
        assert_eq!(
            one(&frame(&[b"MGET", b"1", b"2"])),
            Command::Read {
                keys: vec![WireKey::from_bytes(b"1"), WireKey::from_bytes(b"2")],
                cas: false,
                single: false,
            }
        );
        assert_eq!(
            one(&frame(&[b"MSET", b"1", b"10", b"2", b"20"])),
            Command::WriteMany {
                items: vec![
                    (WireKey::from_bytes(b"1"), b"10".to_vec()),
                    (WireKey::from_bytes(b"2"), b"20".to_vec()),
                ],
            }
        );
        assert_eq!(
            one(&frame(&[b"DEL", b"1", b"2"])),
            Command::Delete {
                keys: vec![WireKey::from_bytes(b"1"), WireKey::from_bytes(b"2")],
                noreply: false,
            }
        );
        assert_eq!(
            one(&frame(&[b"EXPIRE", b"1", b"60"])),
            Command::Touch {
                key: WireKey::from_bytes(b"1"),
                ttl: Some(Duration::from_secs(60)),
                noreply: false,
            }
        );
    }

    #[test]
    fn arity_and_value_errors_are_recoverable() {
        for bad in [
            frame(&[b"GET"]),
            frame(&[b"GET", b"1", b"2"]),
            frame(&[b"SET", b"1"]),
            frame(&[b"MSET", b"1", b"10", b"2"]),
            frame(&[b"EXPIRE", b"1"]),
            frame(&[b"FLUSHALL"]),
        ] {
            assert!(
                matches!(one(&bad), Command::Bad { line } if line.starts_with("-ERR")),
                "{:?}",
                String::from_utf8_lossy(&bad)
            );
        }
    }

    #[test]
    fn partial_frames_consume_nothing() {
        let full = frame(&[b"SET", b"1", b"2"]);
        let mut dec = RespDecoder::new();
        // Every strict prefix must return None without consuming.
        for cut in 0..full.len() {
            assert_eq!(dec.decode(&full[..cut]).unwrap(), None, "prefix of {cut} bytes");
        }
        let (cmd, n) = dec.decode(&full).unwrap().unwrap();
        assert_eq!(n, full.len());
        assert!(matches!(cmd, Command::Write { value, .. } if value == b"2"));
    }

    #[test]
    fn bulk_values_are_binary_safe() {
        // CRLF/NUL/high bytes inside a bulk payload do not disturb
        // framing: the $len prefix rules.
        let payload = b"a\r\nb\0c\xffd";
        let cmd = one(&frame(&[b"SET", b"1", payload]));
        assert!(matches!(&cmd, Command::Write { value, .. } if value == payload));

        let cmd = one(&frame(&[b"MSET", b"1", payload, b"2", b"\r\n\r\n"]));
        match cmd {
            Command::WriteMany { items } => {
                assert_eq!(items[0].1, payload.to_vec());
                assert_eq!(items[1].1, b"\r\n\r\n".to_vec());
            }
            c => panic!("expected WriteMany, got {c:?}"),
        }
    }

    #[test]
    fn pipelined_frames_decode_back_to_back() {
        let mut wire = frame(&[b"SET", b"1", b"10"]);
        wire.extend_from_slice(&frame(&[b"GET", b"1"]));
        wire.extend_from_slice(&frame(&[b"PING"]));
        let mut dec = RespDecoder::new();
        let mut rest = &wire[..];
        let mut cmds = Vec::new();
        while let Some((cmd, n)) = dec.decode(rest).unwrap() {
            rest = &rest[n..];
            cmds.push(cmd);
        }
        assert!(rest.is_empty());
        assert_eq!(cmds.len(), 3);
        assert!(matches!(cmds[2], Command::Ping));
    }

    #[test]
    fn corrupt_framing_is_fatal() {
        let mut dec = RespDecoder::new();
        assert!(dec.decode(b"GET 1\r\n").is_err(), "inline commands are not RESP arrays");
        assert!(dec.decode(b"*2\r\n+OK\r\n").is_err(), "non-bulk element");
        assert!(dec.decode(b"*x\r\n").is_err(), "non-digit count");
        assert!(dec.decode(b"*2000\r\n").is_err(), "count beyond cap");
        assert!(dec.decode(b"*1\r\n$99999999\r\n").is_err(), "length beyond digits cap");
        assert!(dec.decode(b"*1\r\n$3\r\nabcd\r\n").is_err(), "bulk not CRLF-terminated");
    }

    #[test]
    fn oversized_key_is_recoverable() {
        let big = vec![b'k'; MAX_KEY_LEN + 1];
        let cmd = one(&frame(&[b"GET", &big]));
        assert!(matches!(cmd, Command::Bad { line } if line.contains("key too long")));
    }

    #[test]
    fn encoders_produce_protocol_frames() {
        let mut out = Vec::new();
        encode_ok(&mut out);
        encode_pong(&mut out);
        encode_int(&mut out, 2);
        encode_bulk(&mut out, Some(42));
        encode_bulk(&mut out, None);
        encode_array_header(&mut out, 2);
        assert_eq!(out, b"+OK\r\n+PONG\r\n:2\r\n$2\r\n42\r\n$-1\r\n*2\r\n");
        let mut out = Vec::new();
        encode_bulk_str(&mut out, "gets:1\r\n");
        assert_eq!(out, b"$8\r\ngets:1\r\n\r\n");

        let mut out = Vec::new();
        encode_bulk_bytes(&mut out, Some(b"x\r\n\0y"));
        encode_bulk_bytes(&mut out, None);
        assert_eq!(out, b"$5\r\nx\r\n\0y\r\n$-1\r\n");
    }

    #[test]
    fn info_parses_to_stats() {
        assert_eq!(one(&frame(&[b"INFO"])), Command::Stats);
        assert_eq!(one(&frame(&[b"info"])), Command::Stats);
    }
}
