//! Memcached text-protocol codec.
//!
//! Supported subset (DESIGN.md §Network front end): `get`/`gets`
//! (multi-key), `set`, `add`, `cas`, `delete`, `touch`, `version`,
//! `quit`, all with `noreply` where the protocol defines it. `incr`/
//! `decr`/`append`/`prepend` answer `ERROR` like any unknown command.
//!
//! The decoder is *stateless across calls*: a storage command is two
//! frames (command line + `<bytes>\r\n`-terminated data block), and
//! when the block has not fully arrived the decoder consumes nothing —
//! the connection's [`super::buf::ReadBuf`] retains the header line and
//! the next read reparses it (a handful of bytes; re-framing state
//! would buy nothing). Malformed storage headers with a parseable byte
//! count are re-framed by discarding the announced data block (the
//! connection survives with `CLIENT_ERROR`); an unparseable byte count
//! loses framing and is fatal.
//!
//! Data blocks are **binary-safe**: the byte count in the storage
//! header frames the block, the decoder never scans it for CRLF, and
//! the raw bytes ride in [`Command::Write`] untouched — whether they
//! are storable is the executor's business (a byte-value cache takes
//! anything up to [`MAX_VALUE_LEN`]; a word cache requires decimal).
//!
//! Deviations from memcached, documented here and in DESIGN.md:
//! `exptime` is always relative seconds (no unix-timestamp
//! reinterpretation past 30 days); flags are accepted but not stored
//! (echoed as `0`); the `gets` cas token is the entry's stored word —
//! on a byte-value cache that word is the generation-stamped slab
//! handle (every overwrite or eviction re-stamps it, so stale tokens
//! answer `EXISTS`), on a word cache it is the value itself (immutable
//! words: value-equality is exactly version-equality). The decoder
//! only frames `cas`; the token comparison lives in the executor.

use super::{
    exptime_to_ttl, parse_value, Command, FatalProtocolError, WireKey, MAX_KEY_LEN, MAX_LINE_LEN,
    MAX_VALUE_LEN,
};

/// Memcached text decoder (kept as a struct for codec-API symmetry with
/// future stateful protocols; currently carries no state).
#[derive(Debug, Default)]
pub struct MemcachedDecoder;

impl MemcachedDecoder {
    /// A fresh decoder.
    pub fn new() -> Self {
        Self
    }

    /// Try to decode one command from the front of `buf`. Returns the
    /// command plus the bytes consumed, `Ok(None)` when the frame is
    /// incomplete (consume nothing, wait for more bytes), or a fatal
    /// error when framing is lost.
    pub fn decode(&mut self, buf: &[u8]) -> Result<Option<(Command, usize)>, FatalProtocolError> {
        let Some(nl) = buf.iter().position(|&b| b == b'\n') else {
            if buf.len() > MAX_LINE_LEN {
                return Err(FatalProtocolError(format!(
                    "command line exceeds {MAX_LINE_LEN} bytes without a newline"
                )));
            }
            return Ok(None);
        };
        let consumed = nl + 1;
        let mut line = &buf[..nl];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }

        let mut tokens = line.split(|&b| b == b' ').filter(|t| !t.is_empty());
        let Some(verb) = tokens.next() else {
            // Blank line: harmless, answer ERROR like memcached does.
            return Ok(Some((Command::Bad { line: "ERROR".into() }, consumed)));
        };
        let rest: Vec<&[u8]> = tokens.collect();

        let cmd = match verb {
            b"get" | b"gets" => decode_get(verb == b"gets", &rest),
            b"set" | b"add" | b"cas" => {
                let kind = match verb {
                    b"set" => StorageVerb::Set,
                    b"add" => StorageVerb::Add,
                    _ => StorageVerb::Cas,
                };
                return decode_storage(kind, &rest, consumed, buf);
            }
            b"delete" => decode_delete(&rest),
            b"touch" => decode_touch(&rest),
            b"stats" => Command::Stats,
            b"version" => Command::Version,
            b"quit" => Command::Quit,
            _ => Command::Bad { line: "ERROR".into() },
        };
        Ok(Some((cmd, consumed)))
    }
}

fn decode_get(cas: bool, rest: &[&[u8]]) -> Command {
    if rest.is_empty() {
        return Command::Bad { line: "ERROR".into() };
    }
    let mut keys = Vec::with_capacity(rest.len());
    for raw in rest {
        if raw.len() > MAX_KEY_LEN {
            return Command::Bad { line: "CLIENT_ERROR key too long".into() };
        }
        keys.push(WireKey::from_bytes(raw));
    }
    Command::Read { keys, cas, single: false }
}

fn decode_delete(rest: &[&[u8]]) -> Command {
    // delete <key> [noreply]
    let noreply = rest.last() == Some(&&b"noreply"[..]);
    let args = if noreply { &rest[..rest.len() - 1] } else { rest };
    match args {
        [key] if key.len() <= MAX_KEY_LEN => {
            Command::Delete { keys: vec![WireKey::from_bytes(key)], noreply }
        }
        [_key] => Command::Bad { line: "CLIENT_ERROR key too long".into() },
        _ => Command::Bad { line: "ERROR".into() },
    }
}

fn decode_touch(rest: &[&[u8]]) -> Command {
    // touch <key> <exptime> [noreply]
    let noreply = rest.last() == Some(&&b"noreply"[..]);
    let args = if noreply { &rest[..rest.len() - 1] } else { rest };
    match args {
        [key, exptime] => {
            if key.len() > MAX_KEY_LEN {
                return Command::Bad { line: "CLIENT_ERROR key too long".into() };
            }
            let Some(exp) = parse_i64(exptime) else {
                return Command::Bad { line: "CLIENT_ERROR invalid exptime argument".into() };
            };
            Command::Touch { key: WireKey::from_bytes(key), ttl: exptime_to_ttl(exp), noreply }
        }
        _ => Command::Bad { line: "ERROR".into() },
    }
}

/// Which storage verb a header line carried — they share framing but
/// differ in arity (`cas` has a token argument) and in the command
/// they decode to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StorageVerb {
    Set,
    Add,
    Cas,
}

/// `set|add <key> <flags> <exptime> <bytes> [noreply]` or
/// `cas <key> <flags> <exptime> <bytes> <token> [noreply]` plus its
/// data block. The byte count frames the block, so it must parse even
/// when the rest of the header is bad; if it doesn't, the stream is
/// lost.
fn decode_storage(
    kind: StorageVerb,
    rest: &[&[u8]],
    header_len: usize,
    buf: &[u8],
) -> Result<Option<(Command, usize)>, FatalProtocolError> {
    let noreply = rest.last() == Some(&&b"noreply"[..]);
    let args = if noreply { &rest[..rest.len() - 1] } else { rest };
    let (key, exptime, bytes, token_arg) = match (kind, args) {
        (StorageVerb::Set | StorageVerb::Add, [key, _flags, exptime, bytes]) => {
            (key, exptime, bytes, None)
        }
        (StorageVerb::Cas, [key, _flags, exptime, bytes, token]) => {
            (key, exptime, bytes, Some(token))
        }
        _ => {
            // No trustworthy byte count → cannot skip the data block.
            return Err(FatalProtocolError(
                "malformed storage command (cannot re-frame data block)".into(),
            ));
        }
    };
    let Some(nbytes) = parse_value(bytes).map(|n| n as usize) else {
        return Err(FatalProtocolError("unparseable byte count in storage command".into()));
    };
    if nbytes > MAX_VALUE_LEN {
        return Err(FatalProtocolError(format!(
            "data block of {nbytes} bytes exceeds the {MAX_VALUE_LEN}-byte cap"
        )));
    }

    // Wait (consuming nothing) until the whole block + CRLF is buffered.
    let total = header_len + nbytes + 2;
    if buf.len() < total {
        return Ok(None);
    }
    let data = &buf[header_len..header_len + nbytes];
    if &buf[header_len + nbytes..total] != b"\r\n" {
        return Err(FatalProtocolError(
            "data block not terminated by CRLF (bad byte count?)".into(),
        ));
    }

    // Header errors are detected *after* framing so the connection
    // survives them: the block is consumed either way.
    let cmd = if key.len() > MAX_KEY_LEN {
        Command::Bad { line: "CLIENT_ERROR key too long".into() }
    } else if let Some(exp) = parse_i64(exptime) {
        match kind {
            StorageVerb::Set | StorageVerb::Add => Command::Write {
                key: WireKey::from_bytes(key),
                value: data.to_vec(),
                ttl: exptime_to_ttl(exp),
                add_only: kind == StorageVerb::Add,
                noreply,
            },
            StorageVerb::Cas => match token_arg.and_then(|t| parse_value(t)) {
                Some(token) => Command::Cas {
                    key: WireKey::from_bytes(key),
                    value: data.to_vec(),
                    ttl: exptime_to_ttl(exp),
                    token,
                    noreply,
                },
                None => Command::Bad { line: "CLIENT_ERROR invalid cas token".into() },
            },
        }
    } else {
        Command::Bad { line: "CLIENT_ERROR invalid exptime argument".into() }
    };
    Ok(Some((cmd, total)))
}

fn parse_i64(bytes: &[u8]) -> Option<i64> {
    std::str::from_utf8(bytes).ok().and_then(|s| s.parse::<i64>().ok())
}

/// Append a `VALUE` response block for one hit. `cas` echoes the value
/// as the cas token (values are immutable words; see module docs).
pub fn encode_value(out: &mut Vec<u8>, key_text: &[u8], value: u64, cas: bool) {
    let body = value.to_string();
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key_text);
    out.extend_from_slice(b" 0 ");
    out.extend_from_slice(body.len().to_string().as_bytes());
    if cas {
        out.push(b' ');
        out.extend_from_slice(body.as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body.as_bytes());
    out.extend_from_slice(b"\r\n");
}

/// Append a `VALUE` response block for one byte-value hit. The data
/// block is length-framed and written verbatim — CRLF, NUL, anything
/// goes. `token` is the cas token to echo (the entry's stored word —
/// its generation-stamped slab handle; see module docs), already
/// fetched by the caller so the value and token ride the same fused
/// batch.
pub fn encode_value_bytes(out: &mut Vec<u8>, key_text: &[u8], value: &[u8], token: Option<u64>) {
    out.extend_from_slice(b"VALUE ");
    out.extend_from_slice(key_text);
    out.extend_from_slice(b" 0 ");
    out.extend_from_slice(value.len().to_string().as_bytes());
    if let Some(token) = token {
        out.push(b' ');
        out.extend_from_slice(token.to_string().as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(value);
    out.extend_from_slice(b"\r\n");
}

/// Append the `END` line that closes a `get`/`gets` response.
pub fn encode_end(out: &mut Vec<u8>) {
    out.extend_from_slice(b"END\r\n");
}

/// Append a bare response line (`STORED`, `DELETED`, `ERROR`, …) with
/// its CRLF.
pub fn encode_line(out: &mut Vec<u8>, line: &str) {
    out.extend_from_slice(line.as_bytes());
    out.extend_from_slice(b"\r\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn decode_all(dec: &mut MemcachedDecoder, mut buf: &[u8]) -> Vec<Command> {
        let mut out = Vec::new();
        while let Some((cmd, n)) = dec.decode(buf).expect("no fatal error") {
            buf = &buf[n..];
            out.push(cmd);
        }
        out
    }

    #[test]
    fn get_single_and_multi() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"get 7\r\nget 1 2 3\r\ngets 9\r\n");
        assert_eq!(cmds.len(), 3);
        match &cmds[0] {
            Command::Read { keys, cas, single } => {
                assert_eq!(keys[0].id, 7);
                assert!(!cas && !single);
            }
            c => panic!("expected Read, got {c:?}"),
        }
        match &cmds[1] {
            Command::Read { keys, .. } => {
                assert_eq!(keys.iter().map(|k| k.id).collect::<Vec<_>>(), vec![1, 2, 3]);
            }
            c => panic!("expected Read, got {c:?}"),
        }
        assert!(matches!(&cmds[2], Command::Read { cas: true, .. }));
    }

    #[test]
    fn set_roundtrip_with_ttl_and_noreply() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"set 5 0 30 2\r\n42\r\nset 6 0 0 1 noreply\r\n9\r\n");
        assert_eq!(cmds.len(), 2);
        assert_eq!(
            cmds[0],
            Command::Write {
                key: WireKey::from_bytes(b"5"),
                value: b"42".to_vec(),
                ttl: Some(Duration::from_secs(30)),
                add_only: false,
                noreply: false,
            }
        );
        assert_eq!(
            cmds[1],
            Command::Write {
                key: WireKey::from_bytes(b"6"),
                value: b"9".to_vec(),
                ttl: None,
                add_only: false,
                noreply: true,
            }
        );
    }

    #[test]
    fn add_sets_the_flag() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"add 1 0 0 1\r\n5\r\n");
        assert!(matches!(&cmds[0], Command::Write { add_only: true, .. }));
    }

    #[test]
    fn split_reads_reassemble_across_arbitrary_boundaries() {
        // Feed one byte at a time: every frame straddles "reads".
        let stream = b"set 10 0 0 3\r\n123\r\nget 10 11\r\ndelete 10\r\n";
        let mut dec = MemcachedDecoder::new();
        let mut buf = Vec::new();
        let mut cmds = Vec::new();
        for &b in stream.iter() {
            buf.push(b);
            while let Some((cmd, n)) = dec.decode(&buf).unwrap() {
                buf.drain(..n);
                cmds.push(cmd);
            }
        }
        assert!(buf.is_empty());
        assert_eq!(cmds.len(), 3);
        assert!(matches!(&cmds[0], Command::Write { value, .. } if value == b"123"));
        assert!(matches!(&cmds[1], Command::Read { .. }));
        assert!(matches!(&cmds[2], Command::Delete { .. }));
    }

    #[test]
    fn incomplete_frames_consume_nothing() {
        let mut dec = MemcachedDecoder::new();
        assert_eq!(dec.decode(b"get 1").unwrap(), None);
        assert_eq!(dec.decode(b"").unwrap(), None);
        // A storage command with a short data block stays unconsumed
        // until the whole block (and CRLF) has arrived.
        assert_eq!(dec.decode(b"set 1 0 0 5\r\n12").unwrap(), None);
        assert_eq!(dec.decode(b"set 1 0 0 5\r\n12345").unwrap(), None);
        let (cmd, n) = dec.decode(b"set 1 0 0 5\r\n12345\r\n").unwrap().unwrap();
        assert!(matches!(cmd, Command::Write { value, .. } if value == b"12345"));
        assert_eq!(n, 20);
    }

    #[test]
    fn data_blocks_are_binary_safe() {
        // CRLF, NUL and high bytes inside the block must not confuse
        // framing: the byte count rules, the block is never CRLF-scanned.
        let mut dec = MemcachedDecoder::new();
        let payload = b"a\r\nb\0c\xffd";
        let mut wire = format!("set 1 0 0 {}\r\n", payload.len()).into_bytes();
        wire.extend_from_slice(payload);
        wire.extend_from_slice(b"\r\nget 1\r\n");
        let cmds = decode_all(&mut dec, &wire);
        assert_eq!(cmds.len(), 2);
        assert!(matches!(&cmds[0], Command::Write { value, .. } if value == payload));
        assert!(matches!(&cmds[1], Command::Read { .. }));
    }

    #[test]
    fn bad_exptime_discards_data_block_and_reframes() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"set 1 0 zzz 3\r\nxyz\r\nversion\r\n");
        assert!(matches!(&cmds[0], Command::Bad { line } if line.contains("exptime")));
        assert!(matches!(&cmds[1], Command::Version));
    }

    #[test]
    fn oversized_key_is_rejected_per_command() {
        let mut dec = MemcachedDecoder::new();
        let big = vec![b'k'; MAX_KEY_LEN + 1];
        let mut wire = b"get ".to_vec();
        wire.extend_from_slice(&big);
        wire.extend_from_slice(b"\r\nget 1\r\n");
        let cmds = decode_all(&mut dec, &wire);
        assert!(matches!(&cmds[0], Command::Bad { line } if line.contains("key too long")));
        assert!(matches!(&cmds[1], Command::Read { .. }));
    }

    #[test]
    fn oversized_set_key_reframes_via_byte_count() {
        let mut dec = MemcachedDecoder::new();
        let big = vec![b'k'; MAX_KEY_LEN + 1];
        let mut wire = b"set ".to_vec();
        wire.extend_from_slice(&big);
        wire.extend_from_slice(b" 0 0 3\r\nxyz\r\nversion\r\n");
        let cmds = decode_all(&mut dec, &wire);
        assert!(matches!(&cmds[0], Command::Bad { line } if line.contains("key too long")));
        assert!(matches!(&cmds[1], Command::Version));
    }

    #[test]
    fn unknown_command_answers_error() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"incr 1 5\r\nflush_all\r\n");
        assert_eq!(cmds.len(), 2);
        for c in &cmds {
            assert!(matches!(c, Command::Bad { line } if line == "ERROR"));
        }
    }

    #[test]
    fn stats_parses() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"stats\r\n");
        assert_eq!(cmds, vec![Command::Stats]);
    }

    #[test]
    fn fatal_errors_lose_the_connection() {
        // Unparseable byte count: framing is unrecoverable.
        let mut dec = MemcachedDecoder::new();
        assert!(dec.decode(b"set 1 0 0 huge\r\n").is_err());

        // Data block bigger than the cap.
        let mut dec = MemcachedDecoder::new();
        assert!(dec.decode(b"set 1 0 0 999999\r\n").is_err());

        // Endless line with no newline.
        let mut dec = MemcachedDecoder::new();
        let long = vec![b'a'; MAX_LINE_LEN + 2];
        assert!(dec.decode(&long).is_err());

        // Byte count that does not match the actual CRLF position.
        let mut dec = MemcachedDecoder::new();
        assert!(dec.decode(b"set 1 0 0 2\r\n12345\r\n").is_err());
    }

    #[test]
    fn delete_touch_version_quit_parse() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"delete 4 noreply\r\ntouch 4 60\r\ntouch 4 0\r\nquit\r\n");
        assert_eq!(
            cmds[0],
            Command::Delete { keys: vec![WireKey::from_bytes(b"4")], noreply: true }
        );
        assert_eq!(
            cmds[1],
            Command::Touch {
                key: WireKey::from_bytes(b"4"),
                ttl: Some(Duration::from_secs(60)),
                noreply: false,
            }
        );
        assert_eq!(
            cmds[2],
            Command::Touch { key: WireKey::from_bytes(b"4"), ttl: None, noreply: false }
        );
        assert_eq!(cmds[3], Command::Quit);
    }

    #[test]
    fn encoders_produce_protocol_lines() {
        let mut out = Vec::new();
        encode_value(&mut out, b"12", 345, false);
        encode_end(&mut out);
        assert_eq!(out, b"VALUE 12 0 3\r\n345\r\nEND\r\n");

        let mut out = Vec::new();
        encode_value(&mut out, b"12", 345, true);
        assert_eq!(out, b"VALUE 12 0 3 345\r\n345\r\n");

        let mut out = Vec::new();
        encode_line(&mut out, "STORED");
        assert_eq!(out, b"STORED\r\n");
    }

    #[test]
    fn byte_value_encoder_is_length_framed() {
        let mut out = Vec::new();
        encode_value_bytes(&mut out, b"k", b"x\r\ny\0", None);
        assert_eq!(out, b"VALUE k 0 5\r\nx\r\ny\0\r\n");

        // The cas token is caller-supplied and echoed verbatim.
        let mut out = Vec::new();
        encode_value_bytes(&mut out, b"k1", b"same", Some(77));
        assert_eq!(out, b"VALUE k1 0 4 77\r\nsame\r\n");
    }

    #[test]
    fn cas_decodes_with_token_and_noreply() {
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"cas 5 0 30 2 91\r\n42\r\ncas 6 0 0 1 7 noreply\r\n9\r\n");
        assert_eq!(
            cmds[0],
            Command::Cas {
                key: WireKey::from_bytes(b"5"),
                value: b"42".to_vec(),
                ttl: Some(Duration::from_secs(30)),
                token: 91,
                noreply: false,
            }
        );
        assert_eq!(
            cmds[1],
            Command::Cas {
                key: WireKey::from_bytes(b"6"),
                value: b"9".to_vec(),
                ttl: None,
                token: 7,
                noreply: true,
            }
        );
    }

    #[test]
    fn cas_bad_token_reframes_via_byte_count() {
        // The token parses after framing: a bad one costs the command,
        // not the connection.
        let mut dec = MemcachedDecoder::new();
        let cmds = decode_all(&mut dec, b"cas 1 0 0 3 nope\r\nxyz\r\nversion\r\n");
        assert!(matches!(&cmds[0], Command::Bad { line } if line.contains("cas token")));
        assert!(matches!(&cmds[1], Command::Version));

        // A cas missing its token has no trustworthy byte count (the
        // 4-arg form would misread `bytes` as the token): fatal.
        let mut dec = MemcachedDecoder::new();
        assert!(dec.decode(b"cas 1 0 0 3\r\n").is_err());
    }
}
