//! Connection state machine: protocol sniffing, pipeline→batch fusion,
//! and the socket-facing read/flush driver.
//!
//! [`Session`] is the socket-free core (unit-testable with byte
//! slices): it drains every complete request the read buffer holds,
//! *fuses* runs of consecutive reads into one
//! [`CacheService::get_batch`] and runs of consecutive unconditional
//! writes (with identical entry options) into one
//! [`CacheService::put_batch_with`], and appends the responses — in
//! request order — to one output chunk. A pipeline of P `get`s thus
//! costs one scatter/gather walk instead of P channel round-trips,
//! which is the whole point of the front end (ISSUE 7).
//!
//! Ordering argument: at most one accumulator (reads *or* writes) is
//! open at any moment. Opening the other kind — or hitting a
//! read-modify-write, which executes unfused — first flushes the open
//! one. Unconditional stores answer `STORED`/`+OK` at accumulation
//! time (their outcome does not depend on execution), so emitted
//! response order always equals request order, and a later read of a
//! fused key observes the write because the write batch executes
//! before the read batch is issued.
//!
//! Values are executed in one of two modes, chosen once per service:
//! when the cache holds byte values ([`CacheService::supports_values`]),
//! raw wire payloads flow through the byte batch path
//! (`get_bytes_batch` / `put_bytes_batch_with`) untouched — binary-safe
//! end to end; over a word-only cache the executor decimal-parses each
//! payload at accumulation time (answering `CLIENT_ERROR` / `-ERR` for
//! non-decimal values, exactly the pre-slab behaviour, now decided here
//! instead of in the codecs).
//!
//! [`Connection`] wraps a `TcpStream` around a session and drives it in
//! one of two modes, chosen by the server backend (DESIGN.md §Network
//! front end, "Event-loop backends"): *readiness* mode
//! ([`Connection::handle`], the epoll path — level-triggered, read-
//! until-`WouldBlock` with a per-cycle byte cap, vectored response
//! flushing, half-close handling) and *completion* mode
//! ([`Connection::ingest`] + [`Connection::output_iovecs`], the
//! io_uring path — the backend performs all socket io and feeds
//! received bytes in / takes queued response slices out). Both modes
//! run the identical [`Session`] fusion core, which is what makes the
//! two backends byte-identical on the wire.
//!
//! [`CacheService::supports_values`]: crate::coordinator::CacheService::supports_values
//!
//! [`CacheService::get_batch`]: crate::coordinator::CacheService::get_batch
//! [`CacheService::put_batch_with`]: crate::coordinator::CacheService::put_batch_with

use super::buf::{ReadBuf, WriteQueue};
use super::memcached::{self, MemcachedDecoder};
use super::resp::{self, RespDecoder};
use super::uring::IoVec;
use super::{parse_value, Command, WireKey};
use crate::coordinator::{CacheService, DegradedPolicy};
use crate::lifetime::EntryOpts;
use std::io;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Max bytes consumed from one socket per event-loop cycle, so one
/// fire-hosing connection cannot starve the rest of an io thread.
const READ_CYCLE_CAP: usize = 256 * 1024;

/// Word-cache refusal of a non-decimal payload, memcached flavour.
const BAD_WORD_VALUE_MC: &str = "CLIENT_ERROR bad data chunk (value must be a decimal u64)";
/// Word-cache refusal of a non-decimal payload, RESP flavour.
const BAD_WORD_VALUE_RESP: &str = "-ERR value is not a decimal u64";

/// Wire protocol spoken by a connection, sniffed from its first byte
/// (`*` opens a RESP array; memcached text never starts with `*`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proto {
    /// Memcached text protocol.
    Memcached,
    /// RESP (redis serialization protocol) arrays-of-bulk-strings.
    Resp,
}

/// What a drain pass decided about the connection's future.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainOutcome {
    /// Keep serving.
    Continue,
    /// Close once queued responses have flushed (`quit`, fatal protocol
    /// error — the error response is already in the output chunk).
    Close,
}

/// Protocol session: decoders plus the fusion executor. Socket-free —
/// the driver ([`Connection`] or a test) owns the buffers.
#[derive(Debug, Default)]
pub struct Session {
    proto: Option<Proto>,
    mc: MemcachedDecoder,
    resp: RespDecoder,
}

impl Session {
    /// A fresh session; the protocol is sniffed from the first byte.
    pub fn new() -> Self {
        Self::default()
    }

    /// The sniffed protocol, once at least one byte has arrived.
    pub fn proto(&self) -> Option<Proto> {
        self.proto
    }

    /// Decode and execute every complete request in `rbuf`, appending
    /// responses to `out` in request order. Incomplete tail bytes stay
    /// in `rbuf` for the next socket read.
    pub fn drain(
        &mut self,
        rbuf: &mut ReadBuf,
        service: &CacheService,
        out: &mut Vec<u8>,
    ) -> DrainOutcome {
        if self.proto.is_none() {
            let Some(&first) = rbuf.bytes().first() else {
                return DrainOutcome::Continue;
            };
            self.proto = Some(if first == b'*' { Proto::Resp } else { Proto::Memcached });
        }
        let proto = self.proto.expect("sniffed above");

        let mut fuser = Fuser::new(service, proto, out);
        let outcome = loop {
            let decoded = match proto {
                Proto::Memcached => self.mc.decode(rbuf.bytes()),
                Proto::Resp => self.resp.decode(rbuf.bytes()),
            };
            match decoded {
                Ok(None) => break DrainOutcome::Continue,
                Ok(Some((cmd, n))) => {
                    rbuf.consume(n);
                    if fuser.execute(cmd) == DrainOutcome::Close {
                        break DrainOutcome::Close;
                    }
                }
                Err(fatal) => {
                    fuser.flush_all();
                    match proto {
                        Proto::Memcached => {
                            memcached::encode_line(fuser.out, &format!("CLIENT_ERROR {}", fatal.0))
                        }
                        Proto::Resp => {
                            resp::encode_error(fuser.out, &format!("-ERR {}", fatal.0))
                        }
                    }
                    break DrainOutcome::Close;
                }
            }
        };
        fuser.flush_all();
        outcome
    }
}

/// One queued read command awaiting the fused `get_batch`.
struct ReadReq {
    keys: Vec<WireKey>,
    cas: bool,
    single: bool,
}

/// The pipeline→batch fusion executor. Holds at most one open
/// accumulator: pending reads *or* pending writes, never both.
struct Fuser<'a> {
    service: &'a CacheService,
    proto: Proto,
    /// Byte-value mode: the cache stores blobs, payloads ride raw.
    bytes_mode: bool,
    out: &'a mut Vec<u8>,
    reads: Vec<ReadReq>,
    read_keys: Vec<u64>,
    writes: Vec<(u64, u64)>,
    byte_writes: Vec<(u64, Vec<u8>)>,
    write_opts: EntryOpts,
}

impl<'a> Fuser<'a> {
    fn new(service: &'a CacheService, proto: Proto, out: &'a mut Vec<u8>) -> Self {
        Self {
            service,
            proto,
            bytes_mode: service.supports_values(),
            out,
            reads: Vec::new(),
            read_keys: Vec::new(),
            writes: Vec::new(),
            byte_writes: Vec::new(),
            write_opts: service.default_opts(),
        }
    }

    /// Execute one command (accumulating fusable ones). `Close` stops
    /// the drain loop.
    fn execute(&mut self, cmd: Command) -> DrainOutcome {
        match cmd {
            // Degraded mode under the Error policy: once the service is
            // halted, every data command answers `unavailable` instead
            // of a fabricated miss/STORED (stores answer at accumulation
            // time, so this must be decided before answering).
            Command::Read { .. }
            | Command::Write { .. }
            | Command::WriteMany { .. }
            | Command::Cas { .. }
            | Command::Delete { .. }
            | Command::Touch { .. }
                if self.strictly_unavailable() =>
            {
                self.refuse(&cmd, "unavailable");
                self.service.metrics().degraded_ops.fetch_add(1, Ordering::Relaxed);
            }
            // Load shedding: over the queue-depth threshold (or under a
            // `shed_test` fault) answer `busy` instead of queueing more
            // work — a bounded, protocol-level refusal the client can
            // retry, rather than unbounded latency.
            Command::Read { .. }
            | Command::Write { .. }
            | Command::WriteMany { .. }
            | Command::Cas { .. }
                if self.service.overloaded() =>
            {
                self.refuse(&cmd, "busy");
                self.service.metrics().shed.fetch_add(1, Ordering::Relaxed);
            }
            Command::Read { keys, cas, single } => {
                self.flush_writes();
                self.read_keys.extend(keys.iter().map(|k| k.id));
                self.reads.push(ReadReq { keys, cas, single });
            }
            Command::Write { key, value, ttl, add_only, noreply } => {
                if add_only {
                    self.flush_all();
                    self.exec_add(key, value, ttl, noreply);
                } else {
                    let opts = self.opts_for(ttl);
                    let stored = if self.bytes_mode {
                        self.accumulate_write_bytes(key.id, value, opts);
                        true
                    } else if let Some(word) = parse_value(&value) {
                        self.accumulate_write(key.id, word, opts);
                        true
                    } else {
                        false
                    };
                    match (stored, self.proto) {
                        (true, Proto::Memcached) => {
                            if !noreply {
                                memcached::encode_line(self.out, "STORED");
                            }
                        }
                        (true, Proto::Resp) => resp::encode_ok(self.out),
                        (false, proto) => {
                            // Word cache, non-decimal payload: refuse at
                            // accumulation so the error keeps request
                            // order (the connection survives).
                            self.flush_all();
                            match proto {
                                Proto::Memcached => {
                                    if !noreply {
                                        memcached::encode_line(self.out, BAD_WORD_VALUE_MC);
                                    }
                                }
                                Proto::Resp => resp::encode_error(self.out, BAD_WORD_VALUE_RESP),
                            }
                        }
                    }
                }
            }
            Command::WriteMany { items } => {
                let opts = self.service.default_opts();
                if self.bytes_mode {
                    for (key, value) in items {
                        self.accumulate_write_bytes(key.id, value, opts);
                    }
                    resp::encode_ok(self.out);
                } else {
                    // All-or-nothing decimal check before accumulating,
                    // so a half-bad MSET stores nothing.
                    let mut words = Vec::with_capacity(items.len());
                    let mut ok = true;
                    for (key, value) in &items {
                        match parse_value(value) {
                            Some(w) => words.push((key.id, w)),
                            None => {
                                ok = false;
                                break;
                            }
                        }
                    }
                    if ok {
                        for (key, word) in words {
                            self.accumulate_write(key, word, opts);
                        }
                        resp::encode_ok(self.out);
                    } else {
                        self.flush_all();
                        resp::encode_error(self.out, BAD_WORD_VALUE_RESP);
                    }
                }
            }
            Command::Cas { key, value, ttl, token, noreply } => {
                self.flush_all();
                self.exec_cas(key, value, ttl, token, noreply);
            }
            Command::Delete { keys, noreply } => {
                self.flush_all();
                self.exec_delete(&keys, noreply);
            }
            Command::Touch { key, ttl, noreply } => {
                self.flush_all();
                self.exec_touch(&key, ttl, noreply);
            }
            Command::Stats => {
                self.flush_all();
                let pairs = self.service.metrics().stat_pairs(self.service.queue_depth());
                match self.proto {
                    Proto::Memcached => {
                        for (name, value) in pairs {
                            memcached::encode_line(self.out, &format!("STAT {name} {value}"));
                        }
                        memcached::encode_end(self.out);
                    }
                    Proto::Resp => {
                        let mut body = String::new();
                        for (name, value) in pairs {
                            body.push_str(name);
                            body.push(':');
                            body.push_str(&value);
                            body.push_str("\r\n");
                        }
                        resp::encode_bulk_str(self.out, &body);
                    }
                }
            }
            // The remaining commands answer immediately, so any open
            // accumulator must flush first to keep responses in
            // request order.
            Command::Ping => {
                self.flush_all();
                resp::encode_pong(self.out);
            }
            Command::Version => {
                self.flush_all();
                memcached::encode_line(self.out, concat!("VERSION ", env!("CARGO_PKG_VERSION")));
            }
            Command::Quit => {
                self.flush_all();
                if self.proto == Proto::Resp {
                    resp::encode_ok(self.out);
                }
                return DrainOutcome::Close;
            }
            Command::Bad { line } => {
                self.flush_all();
                match self.proto {
                    Proto::Memcached => memcached::encode_line(self.out, &line),
                    Proto::Resp => resp::encode_error(self.out, &line),
                }
            }
        }
        DrainOutcome::Continue
    }

    /// Is the service halted *and* configured to surface that as errors?
    fn strictly_unavailable(&self) -> bool {
        self.service.degraded_policy() == DegradedPolicy::Error && self.service.is_stopped()
    }

    /// Answer a refused data command (`busy` shed or `unavailable`
    /// degraded mode) without executing it. Flushes open accumulators
    /// first so responses keep request order; honours `noreply`.
    fn refuse(&mut self, cmd: &Command, why: &str) {
        self.flush_all();
        let noreply = matches!(
            cmd,
            Command::Write { noreply: true, .. }
                | Command::Cas { noreply: true, .. }
                | Command::Delete { noreply: true, .. }
                | Command::Touch { noreply: true, .. }
        );
        match self.proto {
            Proto::Memcached => {
                if !noreply {
                    memcached::encode_line(self.out, &format!("SERVER_ERROR {why}"));
                }
            }
            Proto::Resp => resp::encode_error(self.out, &format!("-ERR {why}")),
        }
    }

    fn opts_for(&self, ttl: Option<Duration>) -> EntryOpts {
        match ttl {
            Some(t) => EntryOpts::ttl(t),
            None => self.service.default_opts(),
        }
    }

    /// Add a store to the write accumulator, flushing first if the open
    /// accumulator is reads or carries different entry options.
    fn accumulate_write(&mut self, key: u64, value: u64, opts: EntryOpts) {
        self.flush_reads();
        if !self.writes.is_empty() && opts != self.write_opts {
            self.flush_writes();
        }
        self.write_opts = opts;
        self.writes.push((key, value));
    }

    /// Byte-mode twin of [`Fuser::accumulate_write`]: raw payloads fuse
    /// into one `put_bytes_batch_with`.
    fn accumulate_write_bytes(&mut self, key: u64, value: Vec<u8>, opts: EntryOpts) {
        self.flush_reads();
        if !self.byte_writes.is_empty() && opts != self.write_opts {
            self.flush_writes();
        }
        self.write_opts = opts;
        self.byte_writes.push((key, value));
    }

    fn flush_all(&mut self) {
        self.flush_reads();
        self.flush_writes();
    }

    /// Issue the fused `get_batch` and emit each queued read's response
    /// from its slice of the result, in request order. When a worker or
    /// the service is down, degrades per [`DegradedPolicy`]: misses
    /// (MissThrough) or one error reply per queued read (Error).
    fn flush_reads(&mut self) {
        if self.reads.is_empty() {
            return;
        }
        if self.bytes_mode {
            self.flush_reads_bytes();
        } else {
            self.flush_reads_words();
        }
    }

    /// Word-mode fused read: values encode as decimal text.
    fn flush_reads_words(&mut self) {
        let keys = std::mem::take(&mut self.read_keys);
        let n = keys.len();
        let values = match self.service.try_get_batch(keys) {
            Ok(values) => values,
            Err(_) => {
                self.service.metrics().degraded_ops.fetch_add(1, Ordering::Relaxed);
                if self.service.degraded_policy() == DegradedPolicy::Error {
                    for _ in self.reads.drain(..) {
                        match self.proto {
                            Proto::Memcached => {
                                memcached::encode_line(self.out, "SERVER_ERROR unavailable")
                            }
                            Proto::Resp => resp::encode_error(self.out, "-ERR unavailable"),
                        }
                    }
                    return;
                }
                vec![None; n]
            }
        };
        let mut at = 0;
        for req in self.reads.drain(..) {
            let hits = &values[at..at + req.keys.len()];
            at += req.keys.len();
            match self.proto {
                Proto::Memcached => {
                    for (key, value) in req.keys.iter().zip(hits) {
                        if let Some(v) = value {
                            memcached::encode_value(self.out, &key.text, *v, req.cas);
                        }
                    }
                    memcached::encode_end(self.out);
                }
                Proto::Resp => {
                    if req.single {
                        resp::encode_bulk(self.out, hits[0]);
                    } else {
                        resp::encode_array_header(self.out, hits.len());
                        for v in hits {
                            resp::encode_bulk(self.out, *v);
                        }
                    }
                }
            }
        }
    }

    /// Byte-mode fused read: one `get_bytes_batch`, raw length-framed
    /// payloads in the responses (binary-safe both protocols). When any
    /// queued read is a `gets`, a second fused word batch fetches the
    /// per-entry version tokens — the stored words themselves, i.e. the
    /// generation-stamped slab handles (DESIGN.md §Network front end) —
    /// which is what [`Fuser::exec_cas`] later compares against.
    fn flush_reads_bytes(&mut self) {
        let keys = std::mem::take(&mut self.read_keys);
        let n = keys.len();
        let tokens: Vec<Option<u64>> = if self.reads.iter().any(|r| r.cas) {
            self.service.try_get_batch(keys.clone()).unwrap_or_else(|_| vec![None; n])
        } else {
            Vec::new()
        };
        let values = match self.service.try_get_bytes_batch(keys) {
            Ok(values) => values,
            Err(_) => {
                self.service.metrics().degraded_ops.fetch_add(1, Ordering::Relaxed);
                if self.service.degraded_policy() == DegradedPolicy::Error {
                    for _ in self.reads.drain(..) {
                        match self.proto {
                            Proto::Memcached => {
                                memcached::encode_line(self.out, "SERVER_ERROR unavailable")
                            }
                            Proto::Resp => resp::encode_error(self.out, "-ERR unavailable"),
                        }
                    }
                    return;
                }
                (0..n).map(|_| None).collect()
            }
        };
        let mut at = 0;
        for req in self.reads.drain(..) {
            let base = at;
            let hits = &values[base..base + req.keys.len()];
            at += req.keys.len();
            match self.proto {
                Proto::Memcached => {
                    for (i, (key, value)) in req.keys.iter().zip(hits).enumerate() {
                        if let Some(v) = value {
                            // A hit whose token fetch raced an eviction
                            // falls back to a value hash: a token no
                            // live entry can match, so a cas against it
                            // answers EXISTS (the safe answer).
                            let token = req.cas.then(|| {
                                tokens.get(base + i).copied().flatten().unwrap_or_else(|| {
                                    crate::util::hash::xxh64(v, 0xCA5)
                                })
                            });
                            memcached::encode_value_bytes(self.out, &key.text, v, token);
                        }
                    }
                    memcached::encode_end(self.out);
                }
                Proto::Resp => {
                    if req.single {
                        resp::encode_bulk_bytes(self.out, hits[0].as_deref());
                    } else {
                        resp::encode_array_header(self.out, hits.len());
                        for v in hits {
                            resp::encode_bulk_bytes(self.out, v.as_deref());
                        }
                    }
                }
            }
        }
    }

    /// Issue the fused `put_batch_with` / `put_bytes_batch_with`
    /// (responses were emitted at accumulation time — a batch the
    /// stopped service drops is counted as degraded; the Error policy
    /// refuses *before* answering, in [`Fuser::execute`], so this silent
    /// drop only happens under MissThrough or when the service halts
    /// mid-pipeline).
    fn flush_writes(&mut self) {
        if !self.writes.is_empty() {
            let batch = std::mem::take(&mut self.writes);
            if self.service.try_put_batch_with(batch, self.write_opts).is_err() {
                self.service.metrics().degraded_ops.fetch_add(1, Ordering::Relaxed);
            }
        }
        if !self.byte_writes.is_empty() {
            let batch = std::mem::take(&mut self.byte_writes);
            if self.service.try_put_bytes_batch_with(batch, self.write_opts).is_err() {
                self.service.metrics().degraded_ops.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// memcached `add`: store only if absent. Executes unfused; the
    /// presence check and store are not atomic under concurrent writers
    /// (documented best-effort, like the rest of the RMW surface).
    fn exec_add(&mut self, key: WireKey, value: Vec<u8>, ttl: Option<Duration>, noreply: bool) {
        let line = if self.service.get(key.id).is_some() {
            "NOT_STORED"
        } else if self.bytes_mode {
            let opts = self.opts_for(ttl);
            self.service.put_bytes_with(key.id, value, opts);
            "STORED"
        } else if let Some(word) = parse_value(&value) {
            let opts = self.opts_for(ttl);
            self.service.put_with(key.id, word, opts);
            "STORED"
        } else {
            BAD_WORD_VALUE_MC
        };
        if !noreply {
            memcached::encode_line(self.out, line);
        }
    }

    /// memcached `cas`: store only if the entry's version token still
    /// matches the one a prior `gets` handed out. The token is the
    /// entry's stored word: in byte mode a generation-stamped slab
    /// handle (the generation bumps on every free, so any overwrite or
    /// eviction invalidates outstanding tokens); on a word cache the
    /// value itself (immutable words — value equality is exactly
    /// version equality). Like `add`, this executes unfused and the
    /// check + store are not atomic under concurrent writers
    /// (documented best-effort RMW; the slab generation ABA window is
    /// 2^26 frees of one slot, astronomically past the race window).
    fn exec_cas(
        &mut self,
        key: WireKey,
        value: Vec<u8>,
        ttl: Option<Duration>,
        token: u64,
        noreply: bool,
    ) {
        let line = match self.service.get(key.id) {
            None => "NOT_FOUND",
            Some(word) if word != token => "EXISTS",
            Some(_) => {
                let opts = self.opts_for(ttl);
                if self.bytes_mode {
                    self.service.put_bytes_with(key.id, value, opts);
                    "STORED"
                } else if let Some(word) = parse_value(&value) {
                    self.service.put_with(key.id, word, opts);
                    "STORED"
                } else {
                    BAD_WORD_VALUE_MC
                }
            }
        };
        if !noreply {
            memcached::encode_line(self.out, line);
        }
    }

    /// Delete by tombstone: overwrite with a born-expired entry, which
    /// probes as a miss and is the victim of first resort. Requires a
    /// lifetime-capable cache (all k-way variants are; a cache without
    /// TTL support answers a server error instead of lying).
    fn exec_delete(&mut self, keys: &[WireKey], noreply: bool) {
        if !self.service.cache().supports_lifetime() {
            match self.proto {
                Proto::Memcached => {
                    if !noreply {
                        memcached::encode_line(
                            self.out,
                            "SERVER_ERROR delete needs a lifetime-capable cache",
                        );
                    }
                }
                Proto::Resp => resp::encode_error(
                    self.out,
                    "-ERR delete needs a lifetime-capable cache",
                ),
            }
            return;
        }
        let mut removed = 0i64;
        for key in keys {
            if self.service.get(key.id).is_some() {
                removed += 1;
            }
            self.service.put_with(key.id, 0, EntryOpts::ttl(Duration::ZERO));
        }
        match self.proto {
            Proto::Memcached => {
                if !noreply {
                    let line = if removed > 0 { "DELETED" } else { "NOT_FOUND" };
                    memcached::encode_line(self.out, line);
                }
            }
            Proto::Resp => resp::encode_int(self.out, removed),
        }
    }

    /// Touch/EXPIRE: re-store the current value under a new TTL
    /// (get + put_with; best-effort under concurrency). Byte mode
    /// re-stores through the byte path — the value word is a slab
    /// handle there, and re-publishing it verbatim would double-free
    /// the item, so the bytes are fetched and re-allocated instead.
    fn exec_touch(&mut self, key: &WireKey, ttl: Option<Duration>, noreply: bool) {
        let opts = match ttl {
            Some(t) => EntryOpts::ttl(t),
            None => EntryOpts::IMMORTAL,
        };
        let found = if self.bytes_mode {
            match self.service.get_bytes(key.id) {
                Some(value) => {
                    self.service.put_bytes_with(key.id, value, opts);
                    true
                }
                None => false,
            }
        } else {
            match self.service.get(key.id) {
                Some(value) => {
                    self.service.put_with(key.id, value, opts);
                    true
                }
                None => false,
            }
        };
        match self.proto {
            Proto::Memcached => {
                if !noreply {
                    let line = if found { "TOUCHED" } else { "NOT_FOUND" };
                    memcached::encode_line(self.out, line);
                }
            }
            Proto::Resp => resp::encode_int(self.out, if found { 1 } else { 0 }),
        }
    }
}

/// Result of one [`Connection::handle`] cycle, telling the event loop
/// how to update its registration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoStatus {
    /// Connection still open; `false` = deregister and drop.
    pub open: bool,
    /// Responses remain queued: register write interest.
    pub want_write: bool,
}

/// A served TCP connection: socket + buffers + session.
#[derive(Debug)]
pub struct Connection {
    stream: TcpStream,
    rbuf: ReadBuf,
    wq: WriteQueue,
    session: Session,
    /// Peer sent EOF (half-close): serve what's buffered, then close.
    peer_closed: bool,
    /// Close once the write queue drains (quit / fatal error).
    closing: bool,
    /// Read/write syscalls attempted on this connection's socket in
    /// readiness mode (completion-mode connections do no syscalls of
    /// their own; the ring's `io_uring_enter` count lives in the loop).
    syscalls: u64,
}

impl Connection {
    /// Wrap an accepted (nonblocking) stream.
    pub fn new(stream: TcpStream) -> Self {
        Self {
            stream,
            rbuf: ReadBuf::new(),
            wq: WriteQueue::new(),
            session: Session::new(),
            peer_closed: false,
            closing: false,
            syscalls: 0,
        }
    }

    /// The raw fd, for poller registration (`-1` on platforms without
    /// unix fds — unreachable in practice, since the server fails fast
    /// there before registering anything).
    pub fn raw_fd(&self) -> i32 {
        #[cfg(unix)]
        {
            use std::os::fd::AsRawFd;
            self.stream.as_raw_fd()
        }
        #[cfg(not(unix))]
        {
            -1
        }
    }

    /// One event-loop cycle: flush pending responses, read whatever the
    /// socket holds (up to [`READ_CYCLE_CAP`]), drain complete requests
    /// through the fusion path, flush again.
    pub fn handle(&mut self, readable: bool, service: &CacheService) -> IoStatus {
        // Flush first: write readiness may be the only reason we woke.
        if !self.flush() {
            return IoStatus { open: false, want_write: false };
        }

        if readable && !self.peer_closed && !self.closing {
            let mut read = 0;
            loop {
                self.syscalls += 1;
                match self.rbuf.fill_from(&mut self.stream) {
                    Ok(0) => {
                        self.peer_closed = true;
                        break;
                    }
                    Ok(n) => {
                        read += n;
                        if read >= READ_CYCLE_CAP {
                            break; // fairness: resume next cycle
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return IoStatus { open: false, want_write: false },
                }
            }
        }

        if !self.closing && !self.rbuf.is_empty() {
            let mut out = Vec::new();
            let outcome = self.session.drain(&mut self.rbuf, service, &mut out);
            self.wq.push(out);
            if outcome == DrainOutcome::Close {
                self.closing = true;
            }
        }

        if !self.flush() {
            return IoStatus { open: false, want_write: false };
        }

        let drained = self.wq.is_empty();
        if drained && (self.closing || self.peer_closed) {
            return IoStatus { open: false, want_write: false };
        }
        IoStatus { open: true, want_write: !drained }
    }

    /// Drain the write queue; `false` = connection is dead.
    fn flush(&mut self) -> bool {
        self.wq.flush_counted(&mut self.stream, &mut self.syscalls).is_ok()
    }

    /// Bytes of queued, unflushed responses — the event loop's
    /// slow-client signal (a peer that stops reading while we keep
    /// answering accumulates here).
    pub fn queued_bytes(&self) -> usize {
        self.wq.queued_bytes()
    }

    /// Whether a partial request is sitting in the read buffer — the
    /// event loop's per-request-deadline signal (a complete request
    /// would have been drained and answered by [`Connection::handle`]).
    pub fn has_buffered_request(&self) -> bool {
        !self.rbuf.is_empty()
    }

    // ---- completion-mode surface -----------------------------------
    //
    // The io_uring loop never touches the socket directly: the kernel
    // delivers received bytes (fed back via [`Connection::ingest`]) and
    // writes whatever [`Connection::output_iovecs`] describes, then
    // reports progress through [`Connection::advance_output`]. The
    // session/fusion core in between is the exact same code path the
    // readiness loop runs, which is what makes the two backends
    // byte-identical on the wire.

    /// Feed bytes received by the kernel through the same parse →
    /// fuse → respond path as readiness mode. Returns `false` once the
    /// session decided to close (quit / fatal protocol error): the
    /// caller should stop arming receives and drain the write queue.
    pub fn ingest(&mut self, bytes: &[u8], service: &CacheService) -> bool {
        if self.closing {
            return false;
        }
        self.rbuf.push(bytes);
        let mut out = Vec::new();
        let outcome = self.session.drain(&mut self.rbuf, service, &mut out);
        self.wq.push(out);
        if outcome == DrainOutcome::Close {
            self.closing = true;
        }
        !self.closing
    }

    /// Record a zero-length receive completion (peer EOF).
    pub fn note_peer_closed(&mut self) {
        self.peer_closed = true;
    }

    /// Responses are queued and a writev SQE should be armed.
    pub fn has_output(&self) -> bool {
        !self.wq.is_empty()
    }

    /// Describe up to `max` queued response chunks as iovecs for a
    /// writev SQE. The returned pointers borrow the write queue: they
    /// stay valid until [`Connection::advance_output`] /
    /// [`WriteQueue::push`] next mutate it, so the event loop must keep
    /// exactly one write in flight per connection.
    pub fn output_iovecs(&self, out: &mut Vec<IoVec>, max: usize) {
        out.clear();
        out.extend(self.wq.peek_slices(max).map(IoVec::from_slice));
    }

    /// Record `n` bytes written by the kernel.
    pub fn advance_output(&mut self, n: usize) {
        self.wq.advance(n);
    }

    /// Everything this connection will ever say has been said: it is
    /// closing (or the peer already did) and the write queue is empty.
    pub fn done(&self) -> bool {
        (self.closing || self.peer_closed) && self.wq.is_empty()
    }

    /// Session decided to close — stop arming receives.
    pub fn closing(&self) -> bool {
        self.closing
    }

    /// Drain the readiness-mode syscall counter (for per-tick metrics
    /// flushes; always zero for completion-mode connections).
    pub fn take_syscalls(&mut self) -> u64 {
        std::mem::take(&mut self.syscalls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CacheService, ServiceConfig};
    use crate::kway::KwWfsc;
    use crate::policy::Policy;
    use std::sync::Arc;

    fn service() -> CacheService {
        let cache = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        CacheService::start(cache, ServiceConfig { workers: 2, ..ServiceConfig::default() })
    }

    fn byte_service() -> CacheService {
        let cache: Arc<dyn crate::Cache> = Arc::from(crate::kway::build_with_values(
            crate::kway::Variant::Wfsc,
            1024,
            8,
            Policy::Lru,
            1 << 22,
        ));
        CacheService::start(cache, ServiceConfig { workers: 2, ..ServiceConfig::default() })
    }

    fn run(session: &mut Session, service: &CacheService, wire: &[u8]) -> (Vec<u8>, DrainOutcome) {
        let mut rbuf = ReadBuf::new();
        rbuf.push(wire);
        let mut out = Vec::new();
        let outcome = session.drain(&mut rbuf, service, &mut out);
        (out, outcome)
    }

    #[test]
    fn memcached_set_then_get_roundtrip() {
        let svc = service();
        let mut s = Session::new();
        let (out, oc) = run(&mut s, &svc, b"set 7 0 0 2\r\n42\r\nget 7\r\n");
        assert_eq!(oc, DrainOutcome::Continue);
        assert_eq!(out, b"STORED\r\nVALUE 7 0 2\r\n42\r\nEND\r\n");
        assert_eq!(s.proto(), Some(Proto::Memcached));
        svc.shutdown();
    }

    #[test]
    fn pipelined_reads_fuse_but_answer_in_order() {
        let svc = service();
        let mut s = Session::new();
        let (_, _) = run(&mut s, &svc, b"set 1 0 0 2\r\n10\r\nset 2 0 0 2\r\n20\r\n");
        // Three pipelined gets drain as one get_batch; responses keep
        // request order (1, missing 99, 2).
        let (out, _) = run(&mut s, &svc, b"get 1\r\nget 99\r\nget 2\r\n");
        assert_eq!(
            out,
            b"VALUE 1 0 2\r\n10\r\nEND\r\nEND\r\nVALUE 2 0 2\r\n20\r\nEND\r\n".to_vec()
        );
        svc.shutdown();
    }

    #[test]
    fn interleaved_reads_and_writes_keep_order() {
        let svc = service();
        let mut s = Session::new();
        // write → read of the same key in one pipeline: the read must
        // observe the write (write batch flushes before the read batch).
        let wire = b"set 5 0 0 1\r\n9\r\nget 5\r\nset 5 0 0 1\r\n8\r\nget 5\r\n";
        let (out, _) = run(&mut s, &svc, wire);
        assert_eq!(
            out,
            b"STORED\r\nVALUE 5 0 1\r\n9\r\nEND\r\nSTORED\r\nVALUE 5 0 1\r\n8\r\nEND\r\n".to_vec()
        );
        svc.shutdown();
    }

    #[test]
    fn memcached_add_delete_touch() {
        let svc = service();
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"add 3 0 0 1\r\n7\r\nadd 3 0 0 1\r\n8\r\nget 3\r\n");
        assert_eq!(out, b"STORED\r\nNOT_STORED\r\nVALUE 3 0 1\r\n7\r\nEND\r\n");
        let (out, _) = run(&mut s, &svc, b"delete 3\r\ndelete 3\r\nget 3\r\n");
        assert_eq!(out, b"DELETED\r\nNOT_FOUND\r\nEND\r\n");
        let (out, _) = run(&mut s, &svc, b"touch 3 60\r\nset 4 0 0 1\r\n5\r\ntouch 4 60\r\n");
        assert_eq!(out, b"NOT_FOUND\r\nSTORED\r\nTOUCHED\r\n");
        svc.shutdown();
    }

    /// Pull the cas token off the first `VALUE <key> 0 <len> <token>`
    /// line of a `gets` response.
    fn gets_token(out: &[u8]) -> u64 {
        let line = out.split(|&b| b == b'\n').next().expect("a VALUE line");
        let line = std::str::from_utf8(line).unwrap().trim_end();
        line.rsplit(' ').next().unwrap().parse().expect("decimal cas token")
    }

    #[test]
    fn memcached_cas_on_word_cache() {
        let svc = service();
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"set 7 0 0 2\r\n42\r\ngets 7\r\n");
        assert_eq!(out, b"STORED\r\nVALUE 7 0 2 42\r\n42\r\nEND\r\n");
        let token = gets_token(&out[8..]);
        assert_eq!(token, 42, "word-cache cas token is the value itself");

        // Matching token stores; the stale token then answers EXISTS;
        // a missing key answers NOT_FOUND; noreply suppresses the line.
        let wire = format!(
            "cas 7 0 0 2 {token}\r\n43\r\ncas 7 0 0 2 {token}\r\n44\r\n\
             cas 99 0 0 1 5\r\n6\r\ncas 7 0 0 2 43 noreply\r\n45\r\nget 7\r\n"
        );
        let (out, _) = run(&mut s, &svc, wire.as_bytes());
        assert_eq!(
            out,
            b"STORED\r\nEXISTS\r\nNOT_FOUND\r\nVALUE 7 0 2\r\n45\r\nEND\r\n".to_vec()
        );

        // A non-decimal value on a word cache costs the command only.
        let (out, _) = run(&mut s, &svc, b"cas 7 0 0 3 45\r\nabc\r\n");
        assert_eq!(out, format!("{BAD_WORD_VALUE_MC}\r\n").into_bytes());
        svc.shutdown();
    }

    #[test]
    fn memcached_cas_on_byte_cache_uses_handle_generation() {
        let svc = byte_service();
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"set k 0 0 5\r\nhello\r\ngets k\r\n");
        assert!(out.starts_with(b"STORED\r\nVALUE k 0 5 "), "{:?}", String::from_utf8_lossy(&out));
        let token = gets_token(&out[8..]);

        // The slab handle is the token: a matching cas stores, and the
        // store re-stamps the generation, so replaying the same token
        // answers EXISTS even though the old bytes are long gone.
        let wire = format!("cas k 0 0 5 {token}\r\nworld\r\ncas k 0 0 5 {token}\r\nagain\r\n");
        let (out, _) = run(&mut s, &svc, wire.as_bytes());
        assert_eq!(out, b"STORED\r\nEXISTS\r\n".to_vec());

        // The fresh token from a new gets works again.
        let (out, _) = run(&mut s, &svc, b"gets k\r\n");
        assert!(out.starts_with(b"VALUE k 0 5 "));
        let fresh = gets_token(&out);
        assert_ne!(fresh, token, "overwrite must re-stamp the version token");
        let wire = format!("cas k 0 0 2 {fresh}\r\nhi\r\nget k\r\n");
        let (out, _) = run(&mut s, &svc, wire.as_bytes());
        assert_eq!(out, b"STORED\r\nVALUE k 0 2\r\nhi\r\nEND\r\n".to_vec());

        let (out, _) = run(&mut s, &svc, b"cas missing 0 0 1 9\r\nx\r\n");
        assert_eq!(out, b"NOT_FOUND\r\n".to_vec());
        svc.shutdown();
    }

    #[test]
    fn immediate_commands_flush_pending_reads_first() {
        let svc = service();
        let mut s = Session::new();
        let (_, _) = run(&mut s, &svc, b"set 1 0 0 1\r\n5\r\n");
        // `version` answers inline; the pipelined `get` before it must
        // still answer first.
        let (out, _) = run(&mut s, &svc, b"get 1\r\nversion\r\n");
        assert!(
            out.starts_with(b"VALUE 1 0 1\r\n5\r\nEND\r\nVERSION "),
            "{:?}",
            String::from_utf8_lossy(&out)
        );
        svc.shutdown();
    }

    #[test]
    fn memcached_noreply_suppresses_responses() {
        let svc = service();
        let mut s = Session::new();
        let wire = b"set 1 0 0 1 noreply\r\n5\r\ndelete 1 noreply\r\nget 1\r\n";
        let (out, _) = run(&mut s, &svc, wire);
        assert_eq!(out, b"END\r\n");
        svc.shutdown();
    }

    #[test]
    fn memcached_quit_closes_after_responses() {
        let svc = service();
        let mut s = Session::new();
        let (out, oc) = run(&mut s, &svc, b"version\r\nquit\r\nget 1\r\n");
        assert_eq!(oc, DrainOutcome::Close);
        assert!(out.starts_with(b"VERSION "));
        assert!(!out.ends_with(b"END\r\n"), "commands after quit must not execute");
        svc.shutdown();
    }

    #[test]
    fn resp_set_get_mget_roundtrip() {
        let svc = service();
        let mut s = Session::new();
        let (out, _) = run(
            &mut s,
            &svc,
            b"*3\r\n$3\r\nSET\r\n$1\r\n1\r\n$2\r\n10\r\n*2\r\n$3\r\nGET\r\n$1\r\n1\r\n",
        );
        assert_eq!(out, b"+OK\r\n$2\r\n10\r\n");
        assert_eq!(s.proto(), Some(Proto::Resp));
        let (out, _) = run(&mut s, &svc, b"*3\r\n$4\r\nMGET\r\n$1\r\n1\r\n$2\r\n99\r\n");
        assert_eq!(out, b"*2\r\n$2\r\n10\r\n$-1\r\n");
        svc.shutdown();
    }

    #[test]
    fn resp_mset_del_expire_ping() {
        let svc = service();
        let mut s = Session::new();
        let (out, _) = run(
            &mut s,
            &svc,
            b"*5\r\n$4\r\nMSET\r\n$1\r\n1\r\n$2\r\n10\r\n$1\r\n2\r\n$2\r\n20\r\n",
        );
        assert_eq!(out, b"+OK\r\n");
        let (out, _) = run(&mut s, &svc, b"*3\r\n$3\r\nDEL\r\n$1\r\n1\r\n$2\r\n99\r\n");
        assert_eq!(out, b":1\r\n");
        let (out, _) = run(&mut s, &svc, b"*3\r\n$6\r\nEXPIRE\r\n$1\r\n2\r\n$2\r\n60\r\n");
        assert_eq!(out, b":1\r\n");
        let (out, _) = run(&mut s, &svc, b"*1\r\n$4\r\nPING\r\n");
        assert_eq!(out, b"+PONG\r\n");
        svc.shutdown();
    }

    #[test]
    fn resp_set_with_ttl_expires() {
        let svc = service();
        let mut s = Session::new();
        let (out, _) = run(
            &mut s,
            &svc,
            b"*5\r\n$3\r\nSET\r\n$1\r\n9\r\n$1\r\n5\r\n$2\r\nPX\r\n$2\r\n30\r\n",
        );
        assert_eq!(out, b"+OK\r\n");
        let (out, _) = run(&mut s, &svc, b"*2\r\n$3\r\nGET\r\n$1\r\n9\r\n");
        assert_eq!(out, b"$1\r\n5\r\n");
        std::thread::sleep(Duration::from_millis(60));
        let (out, _) = run(&mut s, &svc, b"*2\r\n$3\r\nGET\r\n$1\r\n9\r\n");
        assert_eq!(out, b"$-1\r\n", "entry must expire after its PX ttl");
        svc.shutdown();
    }

    #[test]
    fn fatal_error_reports_and_closes() {
        let svc = service();
        let mut s = Session::new();
        let (out, oc) = run(&mut s, &svc, b"set 1 0 0 zz\r\n");
        assert_eq!(oc, DrainOutcome::Close);
        assert!(out.starts_with(b"CLIENT_ERROR"), "{:?}", String::from_utf8_lossy(&out));
        // RESP flavour.
        let mut s = Session::new();
        let (out, oc) = run(&mut s, &svc, b"*1\r\n+oops\r\n");
        assert_eq!(oc, DrainOutcome::Close);
        assert!(out.starts_with(b"-ERR"), "{:?}", String::from_utf8_lossy(&out));
        svc.shutdown();
    }

    #[test]
    fn stats_answers_in_both_protocols() {
        let svc = service();
        let mut s = Session::new();
        let (_, _) = run(&mut s, &svc, b"set 1 0 0 2\r\n10\r\nget 1\r\n");
        let (out, oc) = run(&mut s, &svc, b"stats\r\n");
        assert_eq!(oc, DrainOutcome::Continue);
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("STAT gets 1\r\n"), "{text:?}");
        assert!(text.contains("STAT puts 1\r\n"), "{text:?}");
        assert!(text.contains("STAT hits 1\r\n"), "{text:?}");
        assert!(text.contains("STAT shed 0\r\n"), "{text:?}");
        assert!(text.contains("STAT worker_restarts 0\r\n"), "{text:?}");
        assert!(text.ends_with("END\r\n"), "{text:?}");
        // RESP INFO: same pairs as one name:value bulk string.
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"*1\r\n$4\r\nINFO\r\n");
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with('$'), "{text:?}");
        assert!(text.contains("gets:1\r\n"), "{text:?}");
        assert!(text.contains("queue_depth:0\r\n"), "{text:?}");
        svc.shutdown();
    }

    #[test]
    fn halted_service_answers_misses_under_miss_through() {
        let svc = service();
        let mut s = Session::new();
        let (_, _) = run(&mut s, &svc, b"set 1 0 0 2\r\n10\r\n");
        svc.halt();
        // Reads degrade to misses, stores still answer STORED (the put
        // is dropped and counted); the connection stays usable.
        let (out, oc) = run(&mut s, &svc, b"get 1\r\nset 2 0 0 1\r\n5\r\n");
        assert_eq!(oc, DrainOutcome::Continue);
        assert_eq!(out, b"END\r\nSTORED\r\n");
        assert!(svc.metrics().degraded_ops.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn halted_service_answers_errors_under_error_policy() {
        use crate::coordinator::DegradedPolicy;
        let cache = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let svc = CacheService::start(
            cache,
            ServiceConfig {
                workers: 2,
                degraded: DegradedPolicy::Error,
                ..ServiceConfig::default()
            },
        );
        svc.halt();
        let mut s = Session::new();
        let wire = b"get 1\r\nset 2 0 0 1\r\n5\r\nset 3 0 0 1 noreply\r\n6\r\nversion\r\n";
        let (out, _) = run(&mut s, &svc, wire);
        let text = String::from_utf8(out).unwrap();
        assert!(
            text.starts_with("SERVER_ERROR unavailable\r\nSERVER_ERROR unavailable\r\nVERSION "),
            "noreply suppresses its error line too: {text:?}"
        );
        // RESP flavour.
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"*2\r\n$3\r\nGET\r\n$1\r\n1\r\n");
        assert_eq!(out, b"-ERR unavailable\r\n");
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn shed_test_fault_forces_busy_answers() {
        use crate::fault::FaultPlan;
        let cache = Arc::new(KwWfsc::new(1024, 8, Policy::Lru));
        let faults = Arc::new(FaultPlan::parse("shed_test").unwrap());
        let svc = CacheService::start(
            cache,
            ServiceConfig {
                workers: 2,
                faults: Some(Arc::clone(&faults)),
                ..ServiceConfig::default()
            },
        );
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"set 1 0 0 2\r\n10\r\n");
        assert_eq!(out, b"STORED\r\n");
        faults.arm();
        let (out, _) = run(&mut s, &svc, b"get 1\r\nset 2 0 0 1\r\n5\r\n");
        assert_eq!(out, b"SERVER_ERROR busy\r\nSERVER_ERROR busy\r\n");
        assert_eq!(svc.metrics().shed.load(Ordering::Relaxed), 2);
        faults.disarm();
        let (out, _) = run(&mut s, &svc, b"get 1\r\n");
        assert_eq!(out, b"VALUE 1 0 2\r\n10\r\nEND\r\n", "disarm restores service");
        svc.shutdown();
    }

    #[test]
    fn memcached_byte_values_roundtrip() {
        let svc = byte_service();
        let mut s = Session::new();
        let payload = b"bin\r\n\0\xff!";
        let mut wire = format!("set 7 0 0 {}\r\n", payload.len()).into_bytes();
        wire.extend_from_slice(payload);
        wire.extend_from_slice(b"\r\nget 7\r\n");
        let (out, oc) = run(&mut s, &svc, &wire);
        assert_eq!(oc, DrainOutcome::Continue);
        let mut want = b"STORED\r\nVALUE 7 0 8\r\n".to_vec();
        want.extend_from_slice(payload);
        want.extend_from_slice(b"\r\nEND\r\n");
        assert_eq!(out, want, "binary payload must round-trip verbatim");
        svc.shutdown();
    }

    #[test]
    fn resp_byte_values_roundtrip() {
        let svc = byte_service();
        let mut s = Session::new();
        let (out, _) = run(
            &mut s,
            &svc,
            b"*3\r\n$3\r\nSET\r\n$1\r\n1\r\n$5\r\na\r\n\0b\r\n*2\r\n$3\r\nGET\r\n$1\r\n1\r\n",
        );
        assert_eq!(out, b"+OK\r\n$5\r\na\r\n\0b\r\n");
        // MSET/MGET fuse through the byte batch path too.
        let (out, _) = run(
            &mut s,
            &svc,
            b"*5\r\n$4\r\nMSET\r\n$1\r\n2\r\n$2\r\nxy\r\n$1\r\n3\r\n$1\r\n\0\r\n\
              *3\r\n$4\r\nMGET\r\n$1\r\n2\r\n$1\r\n3\r\n",
        );
        assert_eq!(out, b"+OK\r\n*2\r\n$2\r\nxy\r\n$1\r\n\0\r\n");
        svc.shutdown();
    }

    #[test]
    fn byte_mode_add_delete_touch() {
        let svc = byte_service();
        let mut s = Session::new();
        let (out, _) = run(&mut s, &svc, b"add 3 0 0 3\r\nnew\r\nadd 3 0 0 3\r\nnah\r\nget 3\r\n");
        assert_eq!(out, b"STORED\r\nNOT_STORED\r\nVALUE 3 0 3\r\nnew\r\nEND\r\n");
        let (out, _) = run(&mut s, &svc, b"touch 3 60\r\nget 3\r\n");
        assert_eq!(out, b"TOUCHED\r\nVALUE 3 0 3\r\nnew\r\nEND\r\n");
        let (out, _) = run(&mut s, &svc, b"delete 3\r\nget 3\r\n");
        assert_eq!(out, b"DELETED\r\nEND\r\n");
        svc.shutdown();
    }

    #[test]
    fn word_cache_refuses_non_decimal_at_execution() {
        let svc = service();
        let mut s = Session::new();
        // The decoder accepts the binary-safe block; the executor
        // refuses it for a word cache and the connection survives.
        let (out, oc) = run(&mut s, &svc, b"set 1 0 0 3\r\nabc\r\nget 1\r\n");
        assert_eq!(oc, DrainOutcome::Continue);
        assert_eq!(
            out,
            b"CLIENT_ERROR bad data chunk (value must be a decimal u64)\r\nEND\r\n".to_vec()
        );
        // RESP flavour, including a half-bad MSET that stores nothing.
        let mut s = Session::new();
        let wire = b"*3\r\n$3\r\nSET\r\n$1\r\n1\r\n$3\r\nabc\r\n\
                     *5\r\n$4\r\nMSET\r\n$1\r\n2\r\n$1\r\n5\r\n$1\r\n3\r\n$1\r\nz\r\n\
                     *3\r\n$4\r\nMGET\r\n$1\r\n2\r\n$1\r\n3\r\n";
        let (out, _) = run(&mut s, &svc, wire);
        assert_eq!(
            out,
            b"-ERR value is not a decimal u64\r\n-ERR value is not a decimal u64\r\n\
              *2\r\n$-1\r\n$-1\r\n"
                .to_vec()
        );
        svc.shutdown();
    }

    #[test]
    fn partial_tail_stays_buffered_across_drains() {
        let svc = service();
        let mut s = Session::new();
        let mut rbuf = ReadBuf::new();
        let mut out = Vec::new();
        rbuf.push(b"set 1 0 0 2\r\n4");
        assert_eq!(s.drain(&mut rbuf, &svc, &mut out), DrainOutcome::Continue);
        assert!(out.is_empty(), "no complete request yet");
        rbuf.push(b"2\r\nget 1\r\n");
        s.drain(&mut rbuf, &svc, &mut out);
        assert_eq!(out, b"STORED\r\nVALUE 1 0 2\r\n42\r\nEND\r\n");
        assert!(rbuf.is_empty());
        svc.shutdown();
    }
}
