//! Formal-analysis companion (paper §4): the Chernoff/union-bound of
//! Theorem 4.1 and a Monte-Carlo balls-into-bins experiment that
//! validates (and shows the slack of) the bound.
//!
//! Theorem 4.1: storing C desired items in a k-way cache of size C' = 2C
//! (n = C'/k sets) fails with probability at most (C'/k)·e^(−k/6).

/// The paper's Theorem 4.1 upper bound on the probability that some set
/// overflows when C = C'/2 desired items are hashed into C'/k sets of k
/// ways each (δ = 1 in the Chernoff bound).
pub fn theorem41_bound(c_prime: u64, k: u64) -> f64 {
    let sets = (c_prime / k) as f64;
    sets * (-(k as f64) / 6.0).exp()
}

/// Monte-Carlo estimate of the actual overflow probability: throw `c`
/// balls (desired items) into `c_prime / k` bins uniformly and report the
/// fraction of trials in which any bin exceeds `k`.
pub fn monte_carlo_overflow(c: u64, c_prime: u64, k: u64, trials: u32, seed: u64) -> f64 {
    let sets = (c_prime / k) as usize;
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut failures = 0u32;
    let mut loads = vec![0u32; sets];
    for _ in 0..trials {
        loads.fill(0);
        let mut overflowed = false;
        for _ in 0..c {
            // A uniformly hashed item (hashing a random key is uniform).
            let set = rng.index(sets);
            loads[set] += 1;
            if loads[set] > k as u32 {
                overflowed = true;
                break;
            }
        }
        failures += u32::from(overflowed);
    }
    failures as f64 / trials as f64
}

/// Expected maximum load formula from §4 for C items in n sets:
/// C/n + Θ(√(C·log n / n)); returned without the Θ constant.
pub fn expected_max_load(c: u64, n: u64) -> f64 {
    let mean = c as f64 / n as f64;
    mean + (c as f64 * (n as f64).ln() / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_examples_from_paper() {
        // Paper §4: "a 64-way cache of size 200k items can store any
        // desired 100k items with a probability of over 99%". The formula
        // of Theorem 4.1 itself gives 0.073 here (the paper's prose quotes
        // the *actual* probability, which the text notes the bound is not
        // tight for); the Monte-Carlo bench (balls_bins) shows the real
        // overflow rate is ≪ 1%.
        let bound = theorem41_bound(200_000, 64);
        assert!(bound < 0.08, "bound {bound}");
        // "a 2M sized 128 way set associative cache [stores] any 1M items
        // with a probability of over 99.999%": here even the bound is
        // strong enough.
        let bound = theorem41_bound(2_000_000, 128);
        assert!(bound < 1e-5, "bound {bound}");
    }

    #[test]
    fn paper_example_via_monte_carlo() {
        // The 64-way / 200k / 100k example, scaled 1:16 (6.25k desired
        // items into a 12.5k-slot cache, 64 ways, 195 -> 128 sets... keep
        // the power-of-two constraint: 128 sets of 64 = 8192 slots, 4096
        // items). Same k and same load factor 1/2 as the paper's example;
        // overflow probability should be well under 1%.
        let p = monte_carlo_overflow(4096, 8192, 64, 300, 11);
        assert!(p < 0.01, "empirical overflow {p}");
    }

    #[test]
    fn monte_carlo_is_below_bound() {
        // Small instance so the test is fast: C=2048, C'=4096, k=16,
        // 256 sets. The bound is loose; the empirical rate must be below.
        let k = 16;
        let bound = theorem41_bound(4096, k);
        let mc = monte_carlo_overflow(2048, 4096, k, 200, 7);
        assert!(mc <= bound + 0.05, "mc {mc} vs bound {bound}");
    }

    #[test]
    fn max_load_grows_sublinearly() {
        let a = expected_max_load(100_000, 1024);
        let b = expected_max_load(200_000, 1024);
        assert!(a > 100_000.0 / 1024.0);
        assert!(b < 2.2 * a);
    }
}
