//! Deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from a `--faults` spec string and threaded
//! (as an `Arc`) into the components that host injection points: the
//! service worker loop (`worker_panic`), the io threads (`io_stall`),
//! the load generator (`conn_drop`) and the shedding check
//! (`shed_test`). See DESIGN.md §Overload & fault tolerance for the
//! grammar and the semantics of each fault.
//!
//! **Zero cost when off.** The injection *types* always compile (so
//! configs can carry an `Option<Arc<FaultPlan>>` on every feature
//! graph), but the injection *checks* are compiled to constant
//! `false`/`None` unless the `fault-inject` cargo feature is enabled —
//! the branches dead-code-eliminate out of the hot paths. The feature
//! is on by default so plain `cargo test` exercises the chaos suite;
//! production builds that want the checks erased compile with
//! `--no-default-features --features simd`.
//!
//! Every probabilistic site draws from the *caller's* deterministic
//! [`crate::util::rng::Rng`], so a chaos run is reproducible from its
//! seed.

use crate::lifetime::parse_duration;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// An `io_stall:DUR:pPROB` clause: with probability `prob`, an io thread
/// sleeps for `stall` before processing its next event batch —
/// simulating scheduling hiccups / packet-processing stalls.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IoStall {
    /// How long one injected stall lasts.
    pub stall: Duration,
    /// Per-event-loop-iteration probability of stalling.
    pub prob: f64,
}

/// A parsed fault plan: which faults to inject, with their parameters.
///
/// Construct with [`FaultPlan::parse`], share via `Arc`, then [`arm`]
/// it when the faulty window opens. Injection points are inert until
/// armed, so a server can carry a plan from startup and a chaos driver
/// can open/close the fault window around a measured phase.
///
/// [`arm`]: FaultPlan::arm
#[derive(Debug)]
pub struct FaultPlan {
    /// `worker_panic@DUR`: one worker thread panics `DUR` after the plan
    /// is armed (one-shot per arming).
    pub worker_panic_after: Option<Duration>,
    /// `io_stall:DUR:pPROB`: io threads randomly stall (see [`IoStall`]).
    pub io_stall: Option<IoStall>,
    /// `conn_drop:pPROB`: the load generator drops its connection with
    /// this probability per pipeline round, then reconnects — simulating
    /// flaky clients / network resets.
    pub conn_drop: Option<f64>,
    /// `shed_test`: force the service to report itself overloaded, so
    /// every shed path answers `busy` regardless of real queue depth.
    pub shed_test: bool,
    /// The spec string this plan was parsed from (for reports).
    spec: String,
    /// When the plan was armed; `None` = disarmed (all checks inert).
    armed_at: Mutex<Option<Instant>>,
    /// One-shot latch for `worker_panic` (reset by [`FaultPlan::arm`]).
    panic_fired: AtomicBool,
}

impl FaultPlan {
    /// Parse a comma-separated fault spec, e.g.
    /// `worker_panic@300ms,io_stall:3ms:p0.01,conn_drop:p0.001,shed_test`.
    ///
    /// Grammar (clauses in any order, each at most once):
    /// - `worker_panic@DUR` — DUR as in [`parse_duration`] (`300ms`, `5s`)
    /// - `io_stall:DUR:pPROB` — PROB a float in `[0,1]` after a literal `p`
    /// - `conn_drop:pPROB`
    /// - `shed_test`
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::empty(spec);
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(dur) = clause.strip_prefix("worker_panic@") {
                if plan.worker_panic_after.is_some() {
                    bail!("duplicate worker_panic clause in {spec:?}");
                }
                plan.worker_panic_after = Some(
                    parse_duration(dur)
                        .ok_or_else(|| anyhow!("bad duration {dur:?} in {clause:?}"))?,
                );
            } else if let Some(rest) = clause.strip_prefix("io_stall:") {
                if plan.io_stall.is_some() {
                    bail!("duplicate io_stall clause in {spec:?}");
                }
                let (dur, prob) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow!("io_stall needs DUR:pPROB, got {clause:?}"))?;
                plan.io_stall = Some(IoStall {
                    stall: parse_duration(dur)
                        .ok_or_else(|| anyhow!("bad duration {dur:?} in {clause:?}"))?,
                    prob: parse_prob(prob, clause)?,
                });
            } else if let Some(prob) = clause.strip_prefix("conn_drop:") {
                if plan.conn_drop.is_some() {
                    bail!("duplicate conn_drop clause in {spec:?}");
                }
                plan.conn_drop = Some(parse_prob(prob, clause)?);
            } else if clause == "shed_test" {
                plan.shed_test = true;
            } else {
                bail!(
                    "unknown fault clause {clause:?} (expected worker_panic@DUR, \
                     io_stall:DUR:pPROB, conn_drop:pPROB or shed_test)"
                );
            }
        }
        Ok(plan)
    }

    /// A plan with no faults (all checks inert even when armed).
    pub fn empty(spec: &str) -> Self {
        Self {
            worker_panic_after: None,
            io_stall: None,
            conn_drop: None,
            shed_test: false,
            spec: spec.to_string(),
            armed_at: Mutex::new(None),
            panic_fired: AtomicBool::new(false),
        }
    }

    /// The spec string this plan was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Open the fault window: injection points become live, the
    /// `worker_panic` one-shot is re-armed.
    pub fn arm(&self) {
        self.panic_fired.store(false, Ordering::Relaxed);
        *self.armed_at.lock().unwrap() = Some(Instant::now());
    }

    /// Close the fault window: every injection point goes inert again.
    pub fn disarm(&self) {
        *self.armed_at.lock().unwrap() = None;
    }

    /// Is the fault window currently open?
    pub fn armed(&self) -> bool {
        self.armed_at.lock().unwrap().is_some()
    }

    /// Seconds since the window opened (`None` when disarmed).
    fn armed_elapsed(&self) -> Option<Duration> {
        self.armed_at.lock().unwrap().map(|t| t.elapsed())
    }

    /// Worker-loop injection point: should the calling worker panic now?
    /// Fires at most once per [`FaultPlan::arm`] across all workers.
    #[inline]
    pub fn worker_should_panic(&self) -> bool {
        #[cfg(feature = "fault-inject")]
        {
            let Some(after) = self.worker_panic_after else { return false };
            if self.panic_fired.load(Ordering::Relaxed) {
                return false;
            }
            match self.armed_elapsed() {
                Some(elapsed) if elapsed >= after => {
                    // One-shot: exactly one worker wins the swap.
                    !self.panic_fired.swap(true, Ordering::Relaxed)
                }
                _ => false,
            }
        }
        #[cfg(not(feature = "fault-inject"))]
        false
    }

    /// Io-thread injection point: how long to stall before this event
    /// batch, if at all.
    #[inline]
    pub fn io_stall_for(&self, rng: &mut Rng) -> Option<Duration> {
        #[cfg(feature = "fault-inject")]
        {
            let stall = self.io_stall?;
            if self.armed() && rng.chance(stall.prob) {
                return Some(stall.stall);
            }
            None
        }
        #[cfg(not(feature = "fault-inject"))]
        {
            let _ = rng;
            None
        }
    }

    /// Loadgen injection point: drop the connection before this round?
    #[inline]
    pub fn should_drop_conn(&self, rng: &mut Rng) -> bool {
        #[cfg(feature = "fault-inject")]
        {
            match self.conn_drop {
                Some(p) => self.armed() && rng.chance(p),
                None => false,
            }
        }
        #[cfg(not(feature = "fault-inject"))]
        {
            let _ = rng;
            false
        }
    }

    /// Shed-check injection point: pretend the service is overloaded?
    #[inline]
    pub fn shed_forced(&self) -> bool {
        #[cfg(feature = "fault-inject")]
        {
            self.shed_test && self.armed()
        }
        #[cfg(not(feature = "fault-inject"))]
        false
    }
}

fn parse_prob(s: &str, clause: &str) -> Result<f64> {
    let digits = s
        .strip_prefix('p')
        .ok_or_else(|| anyhow!("probability must look like p0.01 in {clause:?}"))?;
    let p: f64 = digits
        .parse()
        .map_err(|e| anyhow!("bad probability {digits:?} in {clause:?}: {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("probability {p} out of [0,1] in {clause:?}");
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse("worker_panic@5s,io_stall:3ms:p0.01,conn_drop:p0.001,shed_test")
            .unwrap();
        assert_eq!(p.worker_panic_after, Some(Duration::from_secs(5)));
        assert_eq!(
            p.io_stall,
            Some(IoStall { stall: Duration::from_millis(3), prob: 0.01 })
        );
        assert_eq!(p.conn_drop, Some(0.001));
        assert!(p.shed_test);
        assert_eq!(p.spec(), "worker_panic@5s,io_stall:3ms:p0.01,conn_drop:p0.001,shed_test");
    }

    #[test]
    fn empty_and_partial_specs() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.worker_panic_after.is_none() && p.io_stall.is_none());
        assert!(p.conn_drop.is_none() && !p.shed_test);
        let p = FaultPlan::parse("conn_drop:p0.5").unwrap();
        assert_eq!(p.conn_drop, Some(0.5));
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "worker_panic@never",
            "io_stall:3ms",
            "io_stall:3ms:0.01", // missing the p prefix
            "conn_drop:p1.5",
            "conn_drop:pNaN",
            "explode",
            "shed_test,shed_test,conn_drop:p0.1,conn_drop:p0.1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn injection_points_are_inert_until_armed() {
        let p = FaultPlan::parse("worker_panic@0ms,conn_drop:p1.0,shed_test").unwrap();
        let mut rng = Rng::new(7);
        assert!(!p.worker_should_panic());
        assert!(!p.should_drop_conn(&mut rng));
        assert!(!p.shed_forced());
        p.arm();
        assert!(p.shed_forced());
        assert!(p.should_drop_conn(&mut rng));
        // worker_panic is one-shot: exactly one true per arming.
        assert!(p.worker_should_panic());
        assert!(!p.worker_should_panic());
        p.arm(); // re-arming resets the one-shot
        assert!(p.worker_should_panic());
        p.disarm();
        assert!(!p.shed_forced() && !p.should_drop_conn(&mut rng));
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn io_stall_draws_from_caller_rng() {
        let p = FaultPlan::parse("io_stall:2ms:p1.0").unwrap();
        let mut rng = Rng::new(1);
        assert_eq!(p.io_stall_for(&mut rng), None, "disarmed plan must not stall");
        p.arm();
        assert_eq!(p.io_stall_for(&mut rng), Some(Duration::from_millis(2)));
        let never = FaultPlan::parse("io_stall:2ms:p0.0").unwrap();
        never.arm();
        assert_eq!(never.io_stall_for(&mut rng), None);
    }
}
