//! # kway — limited-associativity concurrent software caches
//!
//! A production-grade reproduction of *"Limited Associativity Makes
//! Concurrent Software Caches a Breeze"* (Adas, Einziger & Friedman, 2021).
//!
//! The crate is organized as three layers:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: k-way
//!   set-associative concurrent caches ([`kway`]) in three concurrency
//!   flavours (`KW-WFA`, `KW-WFSC`, `KW-LS`), the fully-associative and
//!   sampled baselines ([`fully`]), re-implementations of the
//!   production-grade comparators Guava / Caffeine / segmented Caffeine
//!   ([`products`]), the TinyLFU admission substrate ([`tinylfu`]), trace
//!   models ([`trace`]), the hit-ratio simulator ([`sim`]), the
//!   multi-threaded throughput harness ([`throughput`]) and the cache
//!   service coordinator ([`coordinator`]). TinyLFU admission is a
//!   first-class concurrent layer: [`tinylfu::TlfuCache`] wraps any
//!   [`Cache`] behind [`tinylfu::AdmissionMode`], so every harness,
//!   service and bench can run the paper's "eviction + TinyLFU admission"
//!   configurations multi-threaded.
//! * **Layer 2 (python/compile/model.py)** — a JAX formulation of the
//!   set-parallel cache simulation and batched policy evaluation, AOT
//!   lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the scan
//!   hot-spots (victim selection, set probe, count-min sketch), called from
//!   layer 2 and validated against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (the `xla`
//! crate) so the rust binary never invokes python at run time.

#![warn(missing_docs)]

pub mod fault;
pub mod figures;
pub mod lifetime;
pub mod util;
pub mod policy;
pub mod kway;
pub mod fully;
pub mod tinylfu;
pub mod products;
pub mod trace;
pub mod sim;
pub mod throughput;
pub mod runtime;
pub mod coordinator;
pub mod metrics;
pub mod net;
pub mod analysis;

pub use lifetime::{BatchEntry, EntryOpts, ValueDist, WeightDist};

/// Common cache interface shared by every implementation in this crate.
///
/// Keys and values are `u64`. Trace-driven cache evaluation (the paper's
/// methodology, Section 5.1.2) treats values as opaque handles; using a
/// fixed-width value lets the wait-free variants store whole entries in
/// plain atomics, which is the rust-idiomatic equivalent of the paper's
/// Java `AtomicReferenceArray<Node>` (Java leans on the GC for node
/// reclamation; we lean on fixed-width atomics — see DESIGN.md §Concurrency).
///
/// # Entry lifetime and weight
///
/// Entries may carry a time-to-live and a weight ([`EntryOpts`], via
/// [`Cache::put_with`] / [`Cache::put_batch_with`]). Implementations
/// that report [`Cache::supports_lifetime`] guarantee an expired key is
/// **never** returned — by `get` or `get_batch` — and bound every set's
/// total entry weight by its capacity share (DESIGN.md §Expiration,
/// §Weighted capacity). Implementations without support treat every
/// entry as immortal and unit-weight; the defaults below encode that.
///
/// ```
/// use kway::{Cache, EntryOpts};
/// use kway::kway::KwWfsc;
/// use kway::policy::Policy;
/// use std::time::Duration;
///
/// let cache = KwWfsc::new(1 << 10, 8, Policy::Lru);
/// cache.put(1, 10); // immortal, weight 1
/// cache.put_with(2, 20, EntryOpts::ttl(Duration::ZERO)); // born expired
/// cache.put_with(3, 30, EntryOpts::weight(4)); // weighs 4 budget units
/// assert_eq!(cache.get(1), Some(10));
/// assert_eq!(cache.get(2), None); // expired keys are never returned
/// assert_eq!(cache.get(3), Some(30));
/// ```
///
/// # Online elastic resizing
///
/// Implementations that report [`Cache::supports_resize`] treat capacity
/// as a runtime dial: [`Cache::resize`] installs a new geometry
/// immediately, entries migrate incrementally ([`Cache::resize_step`]
/// and organically on writes), and reads stay correct mid-migration —
/// linear hashing over the power-of-two set count makes the split
/// deterministic (DESIGN.md §Elastic resizing). Fixed-geometry
/// implementations refuse honestly instead of pretending.
///
/// ```
/// use kway::Cache;
/// use kway::kway::KwWfsc;
/// use kway::policy::Policy;
///
/// let cache = KwWfsc::new(1 << 10, 8, Policy::Lru);
/// cache.put(1, 10);
/// assert!(cache.supports_resize() && cache.resize(1 << 11));
/// while cache.resize_pending() {
///     cache.resize_step(64); // the background driver's increment
/// }
/// assert_eq!(cache.capacity(), 1 << 11);
/// assert_eq!(cache.get(1), Some(10)); // no admitted entry is lost
/// ```
pub trait Cache: Send + Sync {
    /// Retrieve `key`'s value, updating the policy metadata on a hit.
    fn get(&self, key: u64) -> Option<u64>;
    /// Insert or overwrite `key`, evicting a victim if there is no room.
    fn put(&self, key: u64, value: u64);
    /// Insert or overwrite `key` with explicit lifetime/weight options.
    /// `put_with(k, v, EntryOpts::default())` is behaviourally identical
    /// to `put(k, v)` for every implementation. The default ignores the
    /// options (immortal, unit weight) — the honest behaviour of an
    /// implementation without lifetime support; implementations that
    /// report [`Cache::supports_lifetime`] override it.
    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        let _ = opts;
        self.put(key, value);
    }
    /// Batched lookup: append one result per key to `out`, in input order
    /// (`out[i]` answers `keys[i]` when `out` starts empty). The default
    /// walks keys one by one; the k-way implementations override it to
    /// hash the whole chunk up front and software-prefetch each set line
    /// before the first probe, which amortizes hashing and overlaps memory
    /// latency (DESIGN.md §Batched access path). Taking a caller-owned
    /// buffer keeps the hot path allocation-free under reuse.
    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        for &key in keys {
            out.push(self.get(key));
        }
    }
    /// Batched insert of `(key, value)` pairs — same amortization story as
    /// [`Cache::get_batch`].
    fn put_batch(&self, items: &[(u64, u64)]) {
        for &(key, value) in items {
            self.put(key, value);
        }
    }
    /// Batched insert where every item carries its own lifetime/weight
    /// options ([`BatchEntry`]). Same input-order contract as
    /// [`Cache::put_batch`]; the k-way implementations override it with
    /// the prepare-then-probe batched path.
    fn put_batch_with(&self, items: &[BatchEntry]) {
        for item in items {
            self.put_with(item.key, item.value, item.opts);
        }
    }
    /// Maximum number of entries the cache may hold. For
    /// lifetime-supporting implementations this doubles as the total
    /// *weight* budget: with unit weights the two readings coincide.
    /// While an online resize is migrating, implementations report the
    /// larger of the source and target capacities (both tables are live);
    /// the figure converges to the target once migration completes.
    fn capacity(&self) -> usize;
    /// The capacity that was *asked for*, before any internal rounding.
    /// The k-way implementations round the set count to a power of two,
    /// which can inflate [`Cache::capacity`] up to ~2× — reports should
    /// show both figures so resize targets stay honest. Defaults to
    /// [`Cache::capacity`] (exact for implementations that do not round).
    fn requested_capacity(&self) -> usize {
        self.capacity()
    }
    /// Does this implementation support online resizing
    /// ([`Cache::resize`] / [`Cache::resize_step`])? `false` (the
    /// default) is the honest answer for fixed-geometry implementations:
    /// their `resize` refuses rather than silently dropping the request.
    fn supports_resize(&self) -> bool {
        false
    }
    /// Begin an online resize toward `new_capacity` and return whether it
    /// was accepted. Implementations with support change their capacity
    /// *incrementally*: the call installs the new geometry and returns
    /// immediately, entries migrate via [`Cache::resize_step`] and
    /// organically on writes, and reads stay correct throughout
    /// (DESIGN.md §Elastic resizing). If a previous resize is still
    /// migrating, the call drives it to completion first (admin ops
    /// serialize). The default refuses (`false`) — the honest behaviour
    /// of a fixed-geometry implementation.
    fn resize(&self, new_capacity: usize) -> bool {
        let _ = new_capacity;
        false
    }
    /// Drive the migration of an in-flight resize: claim up to `max_sets`
    /// not-yet-split source sets and move their entries into the new
    /// table, returning how many sets this call migrated. `0` means no
    /// resize is pending (or every set is already claimed by concurrent
    /// steppers — poll [`Cache::resize_pending`] to distinguish). Safe to
    /// call from any number of threads; the default does nothing.
    fn resize_step(&self, max_sets: usize) -> usize {
        let _ = max_sets;
        0
    }
    /// Is a resize migration currently in flight? The default is `false`.
    fn resize_pending(&self) -> bool {
        false
    }
    /// Number of entries currently held (approximate under concurrency).
    fn len(&self) -> usize;
    /// Total weight units currently held (approximate under
    /// concurrency). Defaults to [`Cache::len`] — exact for
    /// implementations where every entry weighs 1.
    fn weight(&self) -> u64 {
        self.len() as u64
    }
    /// Does this implementation honour [`EntryOpts`]? When `false` (the
    /// default), `put_with` stores immortal unit-weight entries and
    /// [`Cache::sweep_expired`] is a no-op.
    fn supports_lifetime(&self) -> bool {
        false
    }
    /// Incrementally reclaim expired entries: scan up to `max_sets` sets
    /// (or segments) from an internal cursor and free every expired line
    /// found, returning the number reclaimed. Expiration is *lazy* — a
    /// probe never returns an expired entry and an insert evicts expired
    /// lines first — so calling this is optional: it only recovers
    /// memory earlier on idle caches (DESIGN.md §Expiration). The
    /// default does nothing.
    fn sweep_expired(&self, max_sets: usize) -> usize {
        let _ = max_sets;
        0
    }
    /// True when no entries are present.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Human-readable implementation name (used by benches and reports).
    fn name(&self) -> &'static str;
    /// Which key would be evicted if `key` were inserted right now?
    /// `None` = no eviction required (room available) or no preview
    /// support. Used by the TinyLFU admission wrapper; the preview is
    /// advisory under concurrency (the actual victim may differ), which is
    /// fine for an approximate admission filter.
    fn peek_victim(&self, _key: u64) -> Option<u64> {
        None
    }
    /// Does this cache store byte-blob values ([`Cache::put_bytes`] /
    /// [`Cache::get_bytes`])? `false` (the default) is the honest answer
    /// for word-valued caches: their byte methods refuse instead of
    /// corrupting the word space. The k-way variants report `true` when
    /// built with an attached slab store (`with_value_store`), which
    /// turns the value word into a generation-stamped handle into slab
    /// item memory and makes entry weight the item's *actual* bytes
    /// (DESIGN.md §Value store). A byte-mode cache still accepts word
    /// puts of `0` (the tombstone idiom) but other word values are
    /// reserved for handles.
    fn supports_values(&self) -> bool {
        false
    }
    /// Store a byte value under `key`, immortal. Returns whether the
    /// value was admitted — `false` when the implementation has no byte
    /// support (the default), the value exceeds the largest slab class,
    /// the store is out of memory, or the insert lost to contention
    /// ("it is a cache").
    fn put_bytes(&self, key: u64, value: &[u8]) -> bool {
        self.put_bytes_with(key, value, EntryOpts::default())
    }
    /// [`Cache::put_bytes`] with explicit lifetime options. The entry's
    /// weight is always the slab item's size in 64-byte granules —
    /// callers cannot understate what the value actually holds.
    fn put_bytes_with(&self, key: u64, value: &[u8], opts: EntryOpts) -> bool {
        let _ = (key, value, opts);
        false
    }
    /// Retrieve `key`'s byte value. `None` on miss, expiry, eviction
    /// racing the read (the generation check turns a recycled slot into
    /// a clean miss — never torn bytes), or no byte support.
    fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        let _ = key;
        None
    }
    /// Slab bytes currently held by live values (0 for word caches).
    /// Exact at quiesce; approximate under concurrency, like
    /// [`Cache::weight`].
    fn value_bytes(&self) -> u64 {
        0
    }
}

/// Forward the full `Cache` surface through a shared pointer, so wrapper
/// layers ([`tinylfu::TlfuCache`]) can compose over an already-shared
/// `Arc<dyn Cache>` — the shape the coordinator service and the
/// throughput factories hand caches around in. Every method (including
/// the batched paths and the victim preview) forwards explicitly: falling
/// back to the trait defaults here would silently drop the inner
/// implementation's batching and preview support.
impl Cache for std::sync::Arc<dyn Cache> {
    fn get(&self, key: u64) -> Option<u64> {
        (**self).get(key)
    }
    fn put(&self, key: u64, value: u64) {
        (**self).put(key, value)
    }
    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        (**self).put_with(key, value, opts)
    }
    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        (**self).get_batch(keys, out)
    }
    fn put_batch(&self, items: &[(u64, u64)]) {
        (**self).put_batch(items)
    }
    fn put_batch_with(&self, items: &[BatchEntry]) {
        (**self).put_batch_with(items)
    }
    fn capacity(&self) -> usize {
        (**self).capacity()
    }
    fn requested_capacity(&self) -> usize {
        (**self).requested_capacity()
    }
    fn supports_resize(&self) -> bool {
        (**self).supports_resize()
    }
    fn resize(&self, new_capacity: usize) -> bool {
        (**self).resize(new_capacity)
    }
    fn resize_step(&self, max_sets: usize) -> usize {
        (**self).resize_step(max_sets)
    }
    fn resize_pending(&self) -> bool {
        (**self).resize_pending()
    }
    fn len(&self) -> usize {
        (**self).len()
    }
    fn weight(&self) -> u64 {
        (**self).weight()
    }
    fn supports_lifetime(&self) -> bool {
        (**self).supports_lifetime()
    }
    fn sweep_expired(&self, max_sets: usize) -> usize {
        (**self).sweep_expired(max_sets)
    }
    fn is_empty(&self) -> bool {
        (**self).is_empty()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn peek_victim(&self, key: u64) -> Option<u64> {
        (**self).peek_victim(key)
    }
    fn supports_values(&self) -> bool {
        (**self).supports_values()
    }
    fn put_bytes(&self, key: u64, value: &[u8]) -> bool {
        (**self).put_bytes(key, value)
    }
    fn put_bytes_with(&self, key: u64, value: &[u8], opts: EntryOpts) -> bool {
        (**self).put_bytes_with(key, value, opts)
    }
    fn get_bytes(&self, key: u64) -> Option<Vec<u8>> {
        (**self).get_bytes(key)
    }
    fn value_bytes(&self) -> u64 {
        (**self).value_bytes()
    }
}

/// A single-threaded cache simulation interface used by the hit-ratio
/// simulator. Implementations that are `Cache` get this for free via the
/// blanket impl; purely sequential baselines (linked-list LRU, O(1) LFU)
/// implement it directly to avoid paying for synchronization they do not
/// need.
pub trait SimCache {
    /// Was `key` resident (and not expired)? Updates policy metadata.
    fn sim_get(&mut self, key: u64) -> bool;
    /// Install `key`, evicting if needed.
    fn sim_put(&mut self, key: u64);
    /// Install `key` with lifetime/weight options. The default ignores
    /// them — the honest behaviour of a baseline without lifetime
    /// support; expiry-aware baselines (e.g. [`fully::LruList`]) and the
    /// blanket [`Cache`] impl override it.
    fn sim_put_with(&mut self, key: u64, opts: EntryOpts) {
        let _ = opts;
        self.sim_put(key)
    }
    /// Label used in simulator reports.
    fn sim_name(&self) -> String;
}

impl<C: Cache> SimCache for C {
    fn sim_get(&mut self, key: u64) -> bool {
        self.get(key).is_some()
    }
    fn sim_put(&mut self, key: u64) {
        self.put(key, key)
    }
    fn sim_put_with(&mut self, key: u64, opts: EntryOpts) {
        self.put_with(key, key, opts)
    }
    fn sim_name(&self) -> String {
        self.name().to_string()
    }
}
