//! # kway — limited-associativity concurrent software caches
//!
//! A production-grade reproduction of *"Limited Associativity Makes
//! Concurrent Software Caches a Breeze"* (Adas, Einziger & Friedman, 2021).
//!
//! The crate is organized as three layers:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: k-way
//!   set-associative concurrent caches ([`kway`]) in three concurrency
//!   flavours (`KW-WFA`, `KW-WFSC`, `KW-LS`), the fully-associative and
//!   sampled baselines ([`fully`]), re-implementations of the
//!   production-grade comparators Guava / Caffeine / segmented Caffeine
//!   ([`products`]), the TinyLFU admission substrate ([`tinylfu`]), trace
//!   models ([`trace`]), the hit-ratio simulator ([`sim`]), the
//!   multi-threaded throughput harness ([`throughput`]) and the cache
//!   service coordinator ([`coordinator`]). TinyLFU admission is a
//!   first-class concurrent layer: [`tinylfu::TlfuCache`] wraps any
//!   [`Cache`] behind [`tinylfu::AdmissionMode`], so every harness,
//!   service and bench can run the paper's "eviction + TinyLFU admission"
//!   configurations multi-threaded.
//! * **Layer 2 (python/compile/model.py)** — a JAX formulation of the
//!   set-parallel cache simulation and batched policy evaluation, AOT
//!   lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the scan
//!   hot-spots (victim selection, set probe, count-min sketch), called from
//!   layer 2 and validated against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (the `xla`
//! crate) so the rust binary never invokes python at run time.

pub mod figures;
pub mod util;
pub mod policy;
pub mod kway;
pub mod fully;
pub mod tinylfu;
pub mod products;
pub mod trace;
pub mod sim;
pub mod throughput;
pub mod runtime;
pub mod coordinator;
pub mod metrics;
pub mod analysis;

/// Common cache interface shared by every implementation in this crate.
///
/// Keys and values are `u64`. Trace-driven cache evaluation (the paper's
/// methodology, Section 5.1.2) treats values as opaque handles; using a
/// fixed-width value lets the wait-free variants store whole entries in
/// plain atomics, which is the rust-idiomatic equivalent of the paper's
/// Java `AtomicReferenceArray<Node>` (Java leans on the GC for node
/// reclamation; we lean on fixed-width atomics — see DESIGN.md §Concurrency).
pub trait Cache: Send + Sync {
    /// Retrieve `key`'s value, updating the policy metadata on a hit.
    fn get(&self, key: u64) -> Option<u64>;
    /// Insert or overwrite `key`, evicting a victim if there is no room.
    fn put(&self, key: u64, value: u64);
    /// Batched lookup: append one result per key to `out`, in input order
    /// (`out[i]` answers `keys[i]` when `out` starts empty). The default
    /// walks keys one by one; the k-way implementations override it to
    /// hash the whole chunk up front and software-prefetch each set line
    /// before the first probe, which amortizes hashing and overlaps memory
    /// latency (DESIGN.md §Batched access path). Taking a caller-owned
    /// buffer keeps the hot path allocation-free under reuse.
    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        for &key in keys {
            out.push(self.get(key));
        }
    }
    /// Batched insert of `(key, value)` pairs — same amortization story as
    /// [`Cache::get_batch`].
    fn put_batch(&self, items: &[(u64, u64)]) {
        for &(key, value) in items {
            self.put(key, value);
        }
    }
    /// Maximum number of entries the cache may hold.
    fn capacity(&self) -> usize;
    /// Number of entries currently held (approximate under concurrency).
    fn len(&self) -> usize;
    /// True when no entries are present.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Human-readable implementation name (used by benches and reports).
    fn name(&self) -> &'static str;
    /// Which key would be evicted if `key` were inserted right now?
    /// `None` = no eviction required (room available) or no preview
    /// support. Used by the TinyLFU admission wrapper; the preview is
    /// advisory under concurrency (the actual victim may differ), which is
    /// fine for an approximate admission filter.
    fn peek_victim(&self, _key: u64) -> Option<u64> {
        None
    }
}

/// Forward the full `Cache` surface through a shared pointer, so wrapper
/// layers ([`tinylfu::TlfuCache`]) can compose over an already-shared
/// `Arc<dyn Cache>` — the shape the coordinator service and the
/// throughput factories hand caches around in. Every method (including
/// the batched paths and the victim preview) forwards explicitly: falling
/// back to the trait defaults here would silently drop the inner
/// implementation's batching and preview support.
impl Cache for std::sync::Arc<dyn Cache> {
    fn get(&self, key: u64) -> Option<u64> {
        (**self).get(key)
    }
    fn put(&self, key: u64, value: u64) {
        (**self).put(key, value)
    }
    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        (**self).get_batch(keys, out)
    }
    fn put_batch(&self, items: &[(u64, u64)]) {
        (**self).put_batch(items)
    }
    fn capacity(&self) -> usize {
        (**self).capacity()
    }
    fn len(&self) -> usize {
        (**self).len()
    }
    fn is_empty(&self) -> bool {
        (**self).is_empty()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn peek_victim(&self, key: u64) -> Option<u64> {
        (**self).peek_victim(key)
    }
}

/// A single-threaded cache simulation interface used by the hit-ratio
/// simulator. Implementations that are `Cache` get this for free via the
/// blanket impl; purely sequential baselines (linked-list LRU, O(1) LFU)
/// implement it directly to avoid paying for synchronization they do not
/// need.
pub trait SimCache {
    fn sim_get(&mut self, key: u64) -> bool;
    fn sim_put(&mut self, key: u64);
    fn sim_name(&self) -> String;
}

impl<C: Cache> SimCache for C {
    fn sim_get(&mut self, key: u64) -> bool {
        self.get(key).is_some()
    }
    fn sim_put(&mut self, key: u64) {
        self.put(key, key)
    }
    fn sim_name(&self) -> String {
        self.name().to_string()
    }
}
