//! Deterministic pseudo-random generation: SplitMix64 seeding,
//! Xoshiro256++ core, and a Zipf(α) sampler.
//!
//! Everything here is reproducible from a `u64` seed so every experiment in
//! EXPERIMENTS.md can be regenerated bit-for-bit.

/// Xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion, as recommended by the xoshiro authors.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (no modulo bias
    /// worth caring about at these bounds).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf(α) sampler over `{0, 1, ..., n-1}` (rank 0 is the most popular)
/// using Hörmann's rejection-inversion method — O(1) per sample for any
/// exponent > 0, including α = 1.
///
/// This is the workload backbone: web/storage traces are classically
/// modelled as Zipf-like with α between 0.6 and 1.1.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    h_integral_x1: f64,
    h_integral_num: f64,
    s: f64,
}

impl Zipf {
    /// A Zipf(α) sampler over `{0, .., n-1}`.
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n > 0, "Zipf needs a non-empty universe");
        assert!(alpha > 0.0, "Zipf exponent must be positive");
        let h_integral = |x: f64| -> f64 { helper_h_integral(x, alpha) };
        Self {
            n,
            alpha,
            h_integral_x1: h_integral(1.5) - 1.0,
            h_integral_num: h_integral(n as f64 + 0.5),
            s: 2.0 - helper_h_integral_inverse(h_integral(2.5) - helper_h(2.0, alpha), alpha),
        }
    }

    /// Universe size.
    pub fn universe(&self) -> u64 {
        self.n
    }

    /// Exponent.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Draw a rank in `[0, n)`; rank 0 is the hottest key.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_integral_num
                + rng.f64() * (self.h_integral_x1 - self.h_integral_num);
            let x = helper_h_integral_inverse(u, self.alpha);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.s
                || u >= helper_h_integral(k + 0.5, self.alpha) - helper_h(k, self.alpha)
            {
                return (k as u64) - 1;
            }
        }
    }
}

// Numerically stable helpers, following the Apache Commons RNG
// RejectionInversionZipfSampler formulation (Hörmann & Derflinger).
// H(x) = ((x^(1-α)) - 1) / (1-α) is written as helper2((1-α)·ln x)·ln x with
// helper2(t) = expm1(t)/t, which is exact in the α→1 limit.

/// H(x), the integral of the hat function h(x) = x^(-α).
fn helper_h_integral(x: f64, alpha: f64) -> f64 {
    let log_x = x.ln();
    helper2((1.0 - alpha) * log_x) * log_x
}

/// h(x) = x^(-α).
fn helper_h(x: f64, alpha: f64) -> f64 {
    (-alpha * x.ln()).exp()
}

/// H⁻¹(x).
fn helper_h_integral_inverse(x: f64, alpha: f64) -> f64 {
    let mut t = x * (1.0 - alpha);
    if t < -1.0 {
        // Numerical clamp: the inverse is only evaluated on H's range.
        t = -1.0;
    }
    (helper1(t) * x).exp()
}

/// log1p(x)/x, continued with value 1 at x = 0.
fn helper1(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * (0.5 - x * (1.0 / 3.0 - x * 0.25))
    }
}

/// expm1(x)/x, continued with value 1 at x = 0.
fn helper2(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        1.0 + x * 0.5 * (1.0 + x * (1.0 / 3.0) * (1.0 + x * 0.25))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn zipf_ranks_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 1000);
        }
    }

    #[test]
    fn zipf_rank0_most_popular() {
        for &alpha in &[0.6, 0.8, 1.0, 1.2] {
            let z = Zipf::new(10_000, alpha);
            let mut rng = Rng::new(5);
            let mut counts = vec![0u32; 10_000];
            for _ in 0..200_000 {
                counts[z.sample(&mut rng) as usize] += 1;
            }
            // Head dominance: rank 0 beats rank 10 beats rank 1000.
            assert!(counts[0] > counts[10], "alpha={alpha}");
            assert!(counts[10] > counts[1000], "alpha={alpha}");
        }
    }

    #[test]
    fn zipf_alpha1_frequency_ratio() {
        // For α=1, f(rank 1)/f(rank 10) ≈ 10.
        let z = Zipf::new(100_000, 1.0);
        let mut rng = Rng::new(6);
        let mut counts = vec![0u32; 100];
        let n = 2_000_000;
        for _ in 0..n {
            let r = z.sample(&mut rng);
            if r < 100 {
                counts[r as usize] += 1;
            }
        }
        let ratio = counts[0] as f64 / counts[9] as f64;
        assert!((ratio - 10.0).abs() < 2.0, "ratio {ratio}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut parent = Rng::new(9);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
