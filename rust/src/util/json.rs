//! A minimal JSON reader/writer.
//!
//! The crate's only external dependencies are the ones vendored for the XLA
//! bridge, so instead of pulling in serde we carry a ~200-line JSON subset
//! that covers what `artifacts/manifest.json` and the bench configs need:
//! objects, arrays, strings (with escapes), integers, floats, booleans and
//! null. Object key order is preserved (`Vec<(String, Json)>`) so output is
//! deterministic.

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number with no fractional part.
    Int(i64),
    /// A fractional number.
    Float(f64),
    /// A string.
    Str(String),
    /// An ordered array.
    Array(Vec<Json>),
    /// An object; key order is preserved for deterministic output.
    Object(Vec<(String, Json)>),
}

impl Json {
    /// The string payload, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integral payload (`Int`, or a fraction-free `Float`).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(n) => Some(*n),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// The numeric payload as a float (`Int` or `Float`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(n) => Some(*n as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// The boolean payload, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Array`.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    /// The fields, if this is an `Object`.
    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object()?.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Int(n) => write!(f, "{n}"),
            Json::Float(x) => write!(f, "{x}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Object(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document. Trailing content (other than whitespace) is an
/// error.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at offset {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn peek(b: &[u8], pos: usize) -> Result<u8> {
    b.get(pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    if peek(b, *pos)? != c {
        bail!("expected {:?} at offset {}, found {:?}", c as char, *pos, b[*pos] as char);
    }
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match peek(b, *pos)? {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => bail!("unexpected character {:?} at offset {}", c as char, *pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, word: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(value)
    } else {
        bail!("invalid literal at offset {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if peek(b, *pos)? == b'-' {
        *pos += 1;
    }
    let mut is_float = false;
    while *pos < b.len() {
        match b[*pos] {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                is_float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    if is_float {
        Ok(Json::Float(s.parse::<f64>().map_err(|e| anyhow!("bad float {s:?}: {e}"))?))
    } else {
        Ok(Json::Int(s.parse::<i64>().map_err(|e| anyhow!("bad int {s:?}: {e}"))?))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match peek(b, *pos)? {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match peek(b, *pos)? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|e| anyhow!("bad \\u escape {hex:?}: {e}"))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 code point.
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().ok_or_else(|| anyhow!("unexpected end"))?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if peek(b, *pos)? == b']' {
        *pos += 1;
        return Ok(Json::Array(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match peek(b, *pos)? {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Array(items));
            }
            c => bail!("expected ',' or ']' at offset {}, found {:?}", *pos, c as char),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if peek(b, *pos)? == b'}' {
        *pos += 1;
        return Ok(Json::Object(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match peek(b, *pos)? {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Object(fields));
            }
            c => bail!("expected ',' or '}}' at offset {}, found {:?}", *pos, c as char),
        }
    }
}

/// Current `kway bench --json` schema tag (DESIGN.md §Bench JSON).
/// v3 = v2 plus the honest capacity pair: a top-level
/// `requested_capacity` (the CLI figure, pre-rounding) and a per-row
/// `effective_capacity` (what the built implementation actually holds —
/// power-of-two set rounding can inflate it up to ~2×).
/// v4 = v3 plus the hot-path figures: a per-row `cycles_per_op` (summed
/// worker TSC deltas / total ops; 0 off x86_64), and top-level
/// `probe_kind` (which fingerprint-probe kernel ran: avx2/sse2/swar/
/// scalar) and `pinned` (whether workers were core-pinned) — without
/// them a bench artifact is not comparable across machines or builds.
pub const BENCH_SCHEMA: &str = "kway-bench-v4";

/// Validate a bench document against [`BENCH_SCHEMA`]. `cmd_bench` runs
/// this before writing (a malformed document is a bug, not an artifact)
/// and CI keeps it honest through the unit tests below.
pub fn check_bench_schema(doc: &Json) -> Result<()> {
    let field = |key: &str| doc.get(key).ok_or_else(|| anyhow!("missing field {key:?}"));
    let schema = field("schema")?.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
    if schema != BENCH_SCHEMA {
        bail!("schema {schema:?} != {BENCH_SCHEMA:?}");
    }
    for key in ["name", "trace", "policy", "admission", "weight_dist", "probe_kind"] {
        if field(key)?.as_str().is_none() {
            bail!("field {key:?} must be a string");
        }
    }
    for key in ["capacity", "requested_capacity", "ttl_ms", "duration_ms", "repeats", "seed"] {
        if field(key)?.as_i64().is_none() {
            bail!("field {key:?} must be an integer");
        }
    }
    if field("pinned")?.as_bool().is_none() {
        bail!("field \"pinned\" must be a boolean");
    }
    let results = field("results")?.as_array().ok_or_else(|| anyhow!("results: not an array"))?;
    for (i, row) in results.iter().enumerate() {
        let rfield =
            |key: &str| row.get(key).ok_or_else(|| anyhow!("results[{i}]: missing {key:?}"));
        if rfield("impl")?.as_str().is_none() {
            bail!("results[{i}]: impl must be a string");
        }
        for key in ["threads", "effective_capacity", "p50_ns", "p99_ns"] {
            if rfield(key)?.as_i64().is_none() {
                bail!("results[{i}]: {key:?} must be an integer");
            }
        }
        for key in ["mops_mean", "mops_stddev", "hit_ratio", "cycles_per_op"] {
            if rfield(key)?.as_f64().is_none() {
                bail!("results[{i}]: {key:?} must be numeric");
            }
        }
    }
    Ok(())
}

/// Schema tag of `BENCH_hotpath.json`, the probe-path microbench artifact
/// (`cargo bench --bench microbench -- --json`; DESIGN.md §Hot path).
/// One row per (probe kernel, thread count): ns/op, cycles/op and
/// Mops/s for the same resident-set get loop, so the SIMD speedup is a
/// same-file comparison of the avx2/sse2/swar rows against the scalar
/// row. A `provenance` string records how the numbers were produced.
/// v2 = v1 plus a top-level `hugepages` boolean: whether the cache
/// tables were `madvise(MADV_HUGEPAGE)`-backed — TLB pressure moves the
/// probe numbers, so the setting is part of the artifact's identity.
pub const HOTPATH_SCHEMA: &str = "kway-hotpath-v2";

/// Validate a hot-path document against [`HOTPATH_SCHEMA`]; the
/// microbench runs it before writing, like [`check_bench_schema`].
pub fn check_hotpath_schema(doc: &Json) -> Result<()> {
    let field = |key: &str| doc.get(key).ok_or_else(|| anyhow!("missing field {key:?}"));
    let schema = field("schema")?.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
    if schema != HOTPATH_SCHEMA {
        bail!("schema {schema:?} != {HOTPATH_SCHEMA:?}");
    }
    for key in ["impl", "workload", "provenance"] {
        if field(key)?.as_str().is_none() {
            bail!("field {key:?} must be a string");
        }
    }
    for key in ["capacity", "ways", "working_set", "duration_ms", "seed"] {
        if field(key)?.as_i64().is_none() {
            bail!("field {key:?} must be an integer");
        }
    }
    for key in ["pinned", "hugepages"] {
        if field(key)?.as_bool().is_none() {
            bail!("field {key:?} must be a boolean");
        }
    }
    let results = field("results")?.as_array().ok_or_else(|| anyhow!("results: not an array"))?;
    for (i, row) in results.iter().enumerate() {
        let rfield =
            |key: &str| row.get(key).ok_or_else(|| anyhow!("results[{i}]: missing {key:?}"));
        if rfield("probe")?.as_str().is_none() {
            bail!("results[{i}]: probe must be a string");
        }
        if rfield("threads")?.as_i64().is_none() {
            bail!("results[{i}]: threads must be an integer");
        }
        for key in ["mops", "ns_per_op", "cycles_per_op"] {
            if rfield(key)?.as_f64().is_none() {
                bail!("results[{i}]: {key:?} must be numeric");
            }
        }
    }
    Ok(())
}

/// Schema tag of the wire-serving artifacts (`BENCH_serve*.json`): the
/// backend × connections × pipeline-depth × threads sweep emitted by
/// `cargo bench --bench serve -- --json` and by `kway loadgen --json`
/// (DESIGN.md §Network front end). One row per (proto, backend,
/// connections, pipeline, threads) point. v2 adds the event-loop
/// `backend` and a measured `syscalls_per_op` per row — the io_uring
/// completion-mode claim is that uring rows show fewer syscalls/op
/// than epoll rows at equal pipeline depth, on top of v1's claim that
/// deep pipelines amortize syscalls AND widen the scatter/gather
/// batches handed to the cache workers.
pub const SERVE_SCHEMA: &str = "kway-serve-v2";

/// Validate a wire-serving document against [`SERVE_SCHEMA`]; writers
/// run it before touching disk, like [`check_bench_schema`].
pub fn check_serve_schema(doc: &Json) -> Result<()> {
    let field = |key: &str| doc.get(key).ok_or_else(|| anyhow!("missing field {key:?}"));
    let schema = field("schema")?.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
    if schema != SERVE_SCHEMA {
        bail!("schema {schema:?} != {SERVE_SCHEMA:?}");
    }
    for key in ["addr", "provenance"] {
        if field(key)?.as_str().is_none() {
            bail!("field {key:?} must be a string");
        }
    }
    for key in ["duration_ms", "keyspace", "seed"] {
        if field(key)?.as_i64().is_none() {
            bail!("field {key:?} must be an integer");
        }
    }
    if field("pinned")?.as_bool().is_none() {
        bail!("field \"pinned\" must be a boolean");
    }
    let results = field("results")?.as_array().ok_or_else(|| anyhow!("results: not an array"))?;
    for (i, row) in results.iter().enumerate() {
        let rfield =
            |key: &str| row.get(key).ok_or_else(|| anyhow!("results[{i}]: missing {key:?}"));
        for key in ["proto", "backend"] {
            if rfield(key)?.as_str().is_none() {
                bail!("results[{i}]: {key:?} must be a string");
            }
        }
        for key in ["connections", "pipeline", "threads", "ops", "p50_ns", "p99_ns", "errors"] {
            if rfield(key)?.as_i64().is_none() {
                bail!("results[{i}]: {key:?} must be an integer");
            }
        }
        for key in ["mops", "hit_ratio", "syscalls_per_op"] {
            if rfield(key)?.as_f64().is_none() {
                bail!("results[{i}]: {key:?} must be numeric");
            }
        }
    }
    Ok(())
}

/// Schema tag of `BENCH_chaos.json`, the availability-under-faults
/// artifact written by `kway chaos` (DESIGN.md §Overload & fault
/// tolerance). One scenario per injected fault (plus a fault-free
/// baseline); each scenario reports the before/during/after loadgen
/// phases around the armed fault window — ops, errors, reconnects and
/// the derived availability — plus the service's resilience counters
/// and a `recovered` verdict (the after-phase served cleanly).
pub const CHAOS_SCHEMA: &str = "kway-chaos-v1";

/// Validate a chaos document against [`CHAOS_SCHEMA`]; `kway chaos`
/// runs it before writing, like [`check_bench_schema`], and the CI
/// chaos-smoke job re-validates the emitted file.
pub fn check_chaos_schema(doc: &Json) -> Result<()> {
    let field = |key: &str| doc.get(key).ok_or_else(|| anyhow!("missing field {key:?}"));
    let schema = field("schema")?.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
    if schema != CHAOS_SCHEMA {
        bail!("schema {schema:?} != {CHAOS_SCHEMA:?}");
    }
    if field("provenance")?.as_str().is_none() {
        bail!("field \"provenance\" must be a string");
    }
    if field("seed")?.as_i64().is_none() {
        bail!("field \"seed\" must be an integer");
    }
    if field("smoke")?.as_bool().is_none() {
        bail!("field \"smoke\" must be a boolean");
    }
    let scenarios =
        field("scenarios")?.as_array().ok_or_else(|| anyhow!("scenarios: not an array"))?;
    if scenarios.is_empty() {
        bail!("scenarios must not be empty");
    }
    for (i, sc) in scenarios.iter().enumerate() {
        let sfield =
            |key: &str| sc.get(key).ok_or_else(|| anyhow!("scenarios[{i}]: missing {key:?}"));
        for key in ["name", "faults"] {
            if sfield(key)?.as_str().is_none() {
                bail!("scenarios[{i}]: {key:?} must be a string");
            }
        }
        for key in
            ["worker_restarts", "shed", "degraded_ops", "rejected_conns", "evicted_slow_clients"]
        {
            if sfield(key)?.as_i64().is_none() {
                bail!("scenarios[{i}]: {key:?} must be an integer");
            }
        }
        if sfield("recovered")?.as_bool().is_none() {
            bail!("scenarios[{i}]: \"recovered\" must be a boolean");
        }
        let phases =
            sfield("phases")?.as_array().ok_or_else(|| anyhow!("scenarios[{i}]: phases"))?;
        if phases.len() != 3 {
            bail!("scenarios[{i}]: expected 3 phases (before/during/after), got {}", phases.len());
        }
        for (j, ph) in phases.iter().enumerate() {
            let pfield = |key: &str| {
                ph.get(key).ok_or_else(|| anyhow!("scenarios[{i}].phases[{j}]: missing {key:?}"))
            };
            if pfield("phase")?.as_str().is_none() {
                bail!("scenarios[{i}].phases[{j}]: phase must be a string");
            }
            for key in ["ops", "errors", "reconnects"] {
                if pfield(key)?.as_i64().is_none() {
                    bail!("scenarios[{i}].phases[{j}]: {key:?} must be an integer");
                }
            }
            if pfield("availability")?.as_f64().is_none() {
                bail!("scenarios[{i}].phases[{j}]: availability must be numeric");
            }
        }
    }
    Ok(())
}

/// Schema tag of `BENCH_slab.json`, the byte-value slab artifact
/// written by `cargo bench --bench slab -- --json` (DESIGN.md §Value
/// store). One row per (implementation, value distribution, thread
/// count): get-or-fill throughput with slab-backed byte payloads, plus
/// the slab bytes the cache actually held at the end of the run — the
/// weight-honesty figure that makes rows at different value sizes
/// comparable. `value_budget` records the per-cache slab budget the
/// sweep ran under.
pub const SLAB_SCHEMA: &str = "kway-slab-v1";

/// Validate a slab document against [`SLAB_SCHEMA`]; the bench runs it
/// before writing, like [`check_bench_schema`], and the CI slab-smoke
/// job re-validates the emitted file.
pub fn check_slab_schema(doc: &Json) -> Result<()> {
    let field = |key: &str| doc.get(key).ok_or_else(|| anyhow!("missing field {key:?}"));
    let schema = field("schema")?.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
    if schema != SLAB_SCHEMA {
        bail!("schema {schema:?} != {SLAB_SCHEMA:?}");
    }
    if field("provenance")?.as_str().is_none() {
        bail!("field \"provenance\" must be a string");
    }
    for key in ["capacity", "value_budget", "duration_ms", "seed"] {
        if field(key)?.as_i64().is_none() {
            bail!("field {key:?} must be an integer");
        }
    }
    if field("smoke")?.as_bool().is_none() {
        bail!("field \"smoke\" must be a boolean");
    }
    let results = field("results")?.as_array().ok_or_else(|| anyhow!("results: not an array"))?;
    if results.is_empty() {
        bail!("results must not be empty");
    }
    for (i, row) in results.iter().enumerate() {
        let rfield =
            |key: &str| row.get(key).ok_or_else(|| anyhow!("results[{i}]: missing {key:?}"));
        for key in ["impl", "value_dist"] {
            if rfield(key)?.as_str().is_none() {
                bail!("results[{i}]: {key:?} must be a string");
            }
        }
        for key in ["threads", "ops", "p50_ns", "p99_ns", "value_bytes"] {
            if rfield(key)?.as_i64().is_none() {
                bail!("results[{i}]: {key:?} must be an integer");
            }
        }
        for key in ["mops", "hit_ratio"] {
            if rfield(key)?.as_f64().is_none() {
                bail!("results[{i}]: {key:?} must be numeric");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn strings_with_escapes() {
        assert_eq!(parse(r#""a\nb\t\"c\"""#).unwrap(), Json::Str("a\nb\t\"c\"".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": -1.5}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-1.5));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"a":[1,2.5,"x\n"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    fn bench_doc(schema: &str) -> Json {
        parse(&format!(
            r#"{{"schema":"{schema}","name":"oltp","trace":"oltp",
                "capacity":2048,"requested_capacity":2000,"policy":"lru",
                "admission":"none","ttl_ms":0,"weight_dist":"unit",
                "duration_ms":300,"repeats":3,"seed":42,
                "probe_kind":"avx2","pinned":false,
                "results":[{{"impl":"KW-WFSC","threads":4,
                  "effective_capacity":2048,"mops_mean":12.3,
                  "mops_stddev":0.5,"p50_ns":180,"p99_ns":2100,
                  "cycles_per_op":410.5,"hit_ratio":0.9}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn bench_schema_v4_accepts_and_rejects() {
        assert_eq!(BENCH_SCHEMA, "kway-bench-v4", "schema bumps must update this check");
        check_bench_schema(&bench_doc("kway-bench-v4")).unwrap();
        // Stale schema strings are rejected — the check is version-pinned.
        assert!(check_bench_schema(&bench_doc("kway-bench-v3")).is_err());
        // Dropping a v3 field (the honest capacity pair) is rejected.
        let mut doc = bench_doc("kway-bench-v4");
        if let Json::Object(fields) = &mut doc {
            fields.retain(|(k, _)| k != "requested_capacity");
        }
        assert!(check_bench_schema(&doc).is_err());
        // Dropping a v4 field is rejected: the probe-kernel tag...
        let mut doc = bench_doc("kway-bench-v4");
        if let Json::Object(fields) = &mut doc {
            fields.retain(|(k, _)| k != "probe_kind");
        }
        assert!(check_bench_schema(&doc).is_err());
        // ...the pinned flag (must be an actual boolean)...
        let mut doc = bench_doc("kway-bench-v4");
        if let Json::Object(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "pinned" {
                    *v = Json::Str("false".into());
                }
            }
        }
        assert!(check_bench_schema(&doc).is_err());
        // ...and the per-row figures (cycles_per_op like the v3 capacity).
        for key in ["effective_capacity", "cycles_per_op"] {
            let mut doc = bench_doc("kway-bench-v4");
            if let Json::Object(fields) = &mut doc {
                let results = fields.iter_mut().find(|(k, _)| k == "results").map(|(_, v)| v);
                if let Some(Json::Array(rows)) = results {
                    if let Json::Object(row) = &mut rows[0] {
                        row.retain(|(k, _)| k != key);
                    }
                }
            }
            assert!(check_bench_schema(&doc).is_err(), "dropping {key} must fail");
        }
    }

    fn hotpath_doc(schema: &str) -> Json {
        parse(&format!(
            r#"{{"schema":"{schema}","impl":"KW-WFSC","workload":"hit100",
                "capacity":262144,"ways":8,"working_set":131072,
                "duration_ms":300,"seed":42,"pinned":true,"hugepages":false,
                "provenance":"measured",
                "results":[{{"probe":"scalar","threads":1,"mops":31.0,
                  "ns_per_op":32.2,"cycles_per_op":96.1}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn hotpath_schema_v2_accepts_and_rejects() {
        assert_eq!(HOTPATH_SCHEMA, "kway-hotpath-v2", "schema bumps must update this check");
        check_hotpath_schema(&hotpath_doc("kway-hotpath-v2")).unwrap();
        assert!(check_hotpath_schema(&hotpath_doc("kway-hotpath-v1")).is_err());
        // The v2 field: dropping the hugepages flag is rejected, and it
        // must be an actual boolean, not a string.
        let mut doc = hotpath_doc("kway-hotpath-v2");
        if let Json::Object(fields) = &mut doc {
            fields.retain(|(k, _)| k != "hugepages");
        }
        assert!(check_hotpath_schema(&doc).is_err());
        let mut doc = hotpath_doc("kway-hotpath-v2");
        if let Json::Object(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "hugepages" {
                    *v = Json::Str("false".into());
                }
            }
        }
        assert!(check_hotpath_schema(&doc).is_err());
        // Every row figure is load-bearing: dropping any one is rejected.
        for key in ["probe", "threads", "mops", "ns_per_op", "cycles_per_op"] {
            let mut doc = hotpath_doc("kway-hotpath-v2");
            if let Json::Object(fields) = &mut doc {
                let results = fields.iter_mut().find(|(k, _)| k == "results").map(|(_, v)| v);
                if let Some(Json::Array(rows)) = results {
                    if let Json::Object(row) = &mut rows[0] {
                        row.retain(|(k, _)| k != key);
                    }
                }
            }
            assert!(check_hotpath_schema(&doc).is_err(), "dropping {key} must fail");
        }
        // A provenance-less artifact is rejected: numbers without an
        // origin story are not comparable.
        let mut doc = hotpath_doc("kway-hotpath-v2");
        if let Json::Object(fields) = &mut doc {
            fields.retain(|(k, _)| k != "provenance");
        }
        assert!(check_hotpath_schema(&doc).is_err());
    }

    fn serve_doc(schema: &str) -> Json {
        parse(&format!(
            r#"{{"schema":"{schema}","addr":"127.0.0.1:11211",
                "duration_ms":1000,"keyspace":65536,"seed":42,
                "pinned":false,"provenance":"measured",
                "results":[{{"proto":"memcached","backend":"uring",
                  "connections":8,"pipeline":16,"threads":2,
                  "ops":100000,"mops":1.5,"hit_ratio":0.92,
                  "p50_ns":800,"p99_ns":9000,"errors":0,
                  "syscalls_per_op":0.21}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn serve_schema_v2_accepts_and_rejects() {
        assert_eq!(SERVE_SCHEMA, "kway-serve-v2", "schema bumps must update this check");
        check_serve_schema(&serve_doc("kway-serve-v2")).unwrap();
        // v1 documents predate the backend axis and are rejected.
        assert!(check_serve_schema(&serve_doc("kway-serve-v1")).is_err());
        // Every row figure is load-bearing: dropping any one is rejected.
        for key in [
            "proto",
            "backend",
            "connections",
            "pipeline",
            "threads",
            "ops",
            "mops",
            "hit_ratio",
            "p50_ns",
            "p99_ns",
            "errors",
            "syscalls_per_op",
        ] {
            let mut doc = serve_doc("kway-serve-v2");
            if let Json::Object(fields) = &mut doc {
                let results = fields.iter_mut().find(|(k, _)| k == "results").map(|(_, v)| v);
                if let Some(Json::Array(rows)) = results {
                    if let Json::Object(row) = &mut rows[0] {
                        row.retain(|(k, _)| k != key);
                    }
                }
            }
            assert!(check_serve_schema(&doc).is_err(), "dropping {key} must fail");
        }
        // Top-level provenance and the pinned boolean are required.
        for key in ["provenance", "pinned", "addr"] {
            let mut doc = serve_doc("kway-serve-v2");
            if let Json::Object(fields) = &mut doc {
                fields.retain(|(k, _)| k != key);
            }
            assert!(check_serve_schema(&doc).is_err(), "dropping {key} must fail");
        }
    }

    fn chaos_doc(schema: &str) -> Json {
        parse(&format!(
            r#"{{"schema":"{schema}","smoke":true,"seed":42,
                "provenance":"kway chaos, loopback serve + loadgen",
                "scenarios":[{{"name":"worker_panic",
                  "faults":"worker_panic@50ms",
                  "phases":[
                    {{"phase":"before","ops":1000,"errors":0,"reconnects":0,"availability":1.0}},
                    {{"phase":"during","ops":900,"errors":12,"reconnects":1,"availability":0.987}},
                    {{"phase":"after","ops":1000,"errors":0,"reconnects":0,"availability":1.0}}],
                  "worker_restarts":1,"shed":0,"degraded_ops":3,
                  "rejected_conns":0,"evicted_slow_clients":0,
                  "recovered":true}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn chaos_schema_v1_accepts_and_rejects() {
        assert_eq!(CHAOS_SCHEMA, "kway-chaos-v1", "schema bumps must update this check");
        check_chaos_schema(&chaos_doc("kway-chaos-v1")).unwrap();
        assert!(check_chaos_schema(&chaos_doc("kway-chaos-v0")).is_err());
        // An empty scenario list is not an artifact.
        let mut doc = chaos_doc("kway-chaos-v1");
        if let Json::Object(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "scenarios" {
                    *v = Json::Array(vec![]);
                }
            }
        }
        assert!(check_chaos_schema(&doc).is_err());
        // Every scenario counter and the recovered verdict are required.
        for key in ["name", "faults", "worker_restarts", "recovered", "phases"] {
            let mut doc = chaos_doc("kway-chaos-v1");
            if let Json::Object(fields) = &mut doc {
                let scenarios = fields.iter_mut().find(|(k, _)| k == "scenarios").map(|(_, v)| v);
                if let Some(Json::Array(rows)) = scenarios {
                    if let Json::Object(row) = &mut rows[0] {
                        row.retain(|(k, _)| k != key);
                    }
                }
            }
            assert!(check_chaos_schema(&doc).is_err(), "dropping {key} must fail");
        }
        // A fault window without its recovery phase is rejected: the
        // whole point of the artifact is the before/during/after arc.
        let mut doc = chaos_doc("kway-chaos-v1");
        if let Json::Object(fields) = &mut doc {
            let scenarios = fields.iter_mut().find(|(k, _)| k == "scenarios").map(|(_, v)| v);
            if let Some(Json::Array(rows)) = scenarios {
                if let Json::Object(row) = &mut rows[0] {
                    for (k, v) in row.iter_mut() {
                        if k == "phases" {
                            if let Json::Array(phases) = v {
                                phases.pop();
                            }
                        }
                    }
                }
            }
        }
        assert!(check_chaos_schema(&doc).is_err());
    }

    fn slab_doc(schema: &str) -> Json {
        parse(&format!(
            r#"{{"schema":"{schema}","smoke":true,"seed":42,
                "capacity":4096,"value_budget":4194304,"duration_ms":100,
                "provenance":"cargo bench --bench slab",
                "results":[{{"impl":"KW-WFSC","value_dist":"zipf:4096",
                  "threads":4,"ops":100000,"mops":2.1,"hit_ratio":0.88,
                  "p50_ns":400,"p99_ns":5200,"value_bytes":1048576}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn slab_schema_v1_accepts_and_rejects() {
        assert_eq!(SLAB_SCHEMA, "kway-slab-v1", "schema bumps must update this check");
        check_slab_schema(&slab_doc("kway-slab-v1")).unwrap();
        // Stale schema strings are rejected — the check is version-pinned.
        assert!(check_slab_schema(&slab_doc("kway-slab-v0")).is_err());
        // An empty sweep is not an artifact.
        let mut doc = slab_doc("kway-slab-v1");
        if let Json::Object(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "results" {
                    *v = Json::Array(vec![]);
                }
            }
        }
        assert!(check_slab_schema(&doc).is_err());
        // Every row figure is load-bearing — value_bytes especially, the
        // weight-honesty column: dropping any one is rejected.
        for key in [
            "impl",
            "value_dist",
            "threads",
            "ops",
            "mops",
            "hit_ratio",
            "p50_ns",
            "p99_ns",
            "value_bytes",
        ] {
            let mut doc = slab_doc("kway-slab-v1");
            if let Json::Object(fields) = &mut doc {
                let results = fields.iter_mut().find(|(k, _)| k == "results").map(|(_, v)| v);
                if let Some(Json::Array(rows)) = results {
                    if let Json::Object(row) = &mut rows[0] {
                        row.retain(|(k, _)| k != key);
                    }
                }
            }
            assert!(check_slab_schema(&doc).is_err(), "dropping {key} must fail");
        }
        // Top-level provenance, budget and the smoke flag are required.
        for key in ["provenance", "value_budget", "smoke", "capacity"] {
            let mut doc = slab_doc("kway-slab-v1");
            if let Json::Object(fields) = &mut doc {
                fields.retain(|(k, _)| k != key);
            }
            assert!(check_slab_schema(&doc).is_err(), "dropping {key} must fail");
        }
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\u{1}b".into()).to_string();
        assert_eq!(s, "\"a\\u0001b\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\u{1}b".into()));
    }
}
