//! A small command-line parser (clap is not available in the offline
//! build): subcommand + `--key value` / `--flag` options + positionals.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand, named options, flags and
/// positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first non-`--` token), if any.
    pub command: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Tokens that are neither the subcommand nor options.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// The first non-`--` token becomes the subcommand.
    pub fn parse<I, S>(raw: I) -> Result<Self>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut iter = raw.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|next| !next.starts_with("--")) {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// String-valued option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with a default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with a default.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("invalid value {s:?} for --{key}: {e}")),
        }
    }

    /// Boolean flag presence (`--verbose`).
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option, e.g. `--ways 4,8,16`.
    pub fn get_list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|part| {
                    part.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow!("invalid element {part:?} in --{key}: {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subcommand_options_flags_positionals() {
        // Convention: positionals come before options; a bare `--name`
        // followed by a non-dash token is parsed as `name=token`.
        let a = Args::parse([
            "bench", "extra1", "extra2", "--trace", "wiki_a", "--threads=8", "--verbose",
        ])
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("trace"), Some("wiki_a"));
        assert_eq!(a.get("threads"), Some("8"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn typed_and_list_parsing() {
        let a = Args::parse(["x", "--n", "42", "--ways", "4,8,16"]).unwrap();
        assert_eq!(a.get_parsed_or("n", 0usize).unwrap(), 42);
        assert_eq!(a.get_parsed_or("missing", 7u32).unwrap(), 7);
        assert_eq!(a.get_list_or::<usize>("ways", &[]).unwrap(), vec![4, 8, 16]);
        assert_eq!(a.get_list_or::<usize>("absent", &[1, 2]).unwrap(), vec![1, 2]);
        assert!(a.get_parsed_or("n", 0i8).is_ok());
        let bad = Args::parse(["x", "--n", "notanum"]).unwrap();
        assert!(bad.get_parsed_or("n", 0usize).is_err());
    }

    #[test]
    fn trailing_flag_not_eating_nothing() {
        let a = Args::parse(["run", "--fast"]).unwrap();
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(["--only", "opts"]).unwrap();
        assert_eq!(a.command, None);
        assert_eq!(a.get("only"), Some("opts"));
    }
}
