//! CPU affinity and NUMA memory policy for the benchmark harness.
//!
//! Pinning each worker thread to its own core removes scheduler
//! migrations from the measurement (the paper's throughput methodology
//! pins shards; our `--pin` flag reproduces that), and interleaving
//! table pages across NUMA nodes (`--numa-interleave`) keeps multi-socket
//! runs from accidentally benchmarking one node's memory controller.
//!
//! The offline build has no `libc` crate, so on Linux/x86_64 the two
//! facilities are raw `syscall` instructions (`sched_setaffinity`,
//! `set_mempolicy`); everywhere else they are no-ops. Both are
//! best-effort: a `false` return means the harness runs unpinned, which
//! only widens measurement variance — never correctness.

/// Pin the calling thread to `core` (mod the number of online cores).
/// Returns whether the kernel accepted the mask.
pub fn pin_to_core(core: usize) -> bool {
    imp::pin_to_core(core % num_cores().max(1))
}

/// Ask the kernel to interleave this process's *future* page allocations
/// round-robin across all allowed NUMA nodes (`MPOL_INTERLEAVE`). Call
/// before building the tables so their pages spread. Returns whether the
/// policy was installed (single-node machines typically accept it as a
/// harmless no-op).
pub fn interleave_allocations() -> bool {
    imp::interleave_allocations()
}

/// Number of cores available to this process (>= 1).
pub fn num_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod imp {
    use std::arch::asm;

    const SYS_SCHED_SETAFFINITY: u64 = 203;
    const SYS_SET_MEMPOLICY: u64 = 238;
    const MPOL_INTERLEAVE: u64 = 3;

    /// Three-argument raw syscall. Returns the kernel's raw result
    /// (negative errno on failure).
    ///
    /// # Safety
    ///
    /// The caller must pass argument values valid for `nr`'s ABI; the
    /// two wrappers below only pass pointers to live stack buffers.
    unsafe fn syscall3(nr: u64, a1: u64, a2: u64, a3: u64) -> i64 {
        let ret: i64;
        // rcx and r11 are clobbered by the `syscall` instruction itself.
        unsafe {
            asm!(
                "syscall",
                inlateout("rax") nr as i64 => ret,
                in("rdi") a1,
                in("rsi") a2,
                in("rdx") a3,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret
    }

    pub fn pin_to_core(core: usize) -> bool {
        // 1024-bit CPU mask, the kernel's default CPU_SETSIZE.
        let mut mask = [0u64; 16];
        mask[core / 64] = 1u64 << (core % 64);
        // pid 0 = the calling thread.
        let ret = unsafe {
            syscall3(
                SYS_SCHED_SETAFFINITY,
                0,
                std::mem::size_of_val(&mask) as u64,
                mask.as_ptr() as u64,
            )
        };
        ret == 0
    }

    pub fn interleave_allocations() -> bool {
        // All-ones nodemask; maxnode 65 makes the kernel read exactly one
        // u64 of it (get_nodes consumes maxnode - 1 bits). Bits beyond
        // the allowed nodes are masked off by the kernel.
        let nodemask: u64 = !0;
        let mask_ptr = &nodemask as *const u64 as u64;
        let ret = unsafe { syscall3(SYS_SET_MEMPOLICY, MPOL_INTERLEAVE, mask_ptr, 65) };
        ret == 0
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod imp {
    pub fn pin_to_core(_core: usize) -> bool {
        false
    }

    pub fn interleave_allocations() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_cores_positive() {
        assert!(num_cores() >= 1);
    }

    #[test]
    fn pin_is_best_effort_and_does_not_crash() {
        // Whatever the platform answers, the process must stay healthy
        // and the thread must keep running on *some* core.
        let _ = pin_to_core(0);
        let _ = pin_to_core(num_cores() * 3 + 1); // wraps, never out of range
        let x: u64 = (0..1000u64).sum();
        assert_eq!(x, 499_500);
    }

    #[test]
    fn pinned_threads_each_accept_a_distinct_core() {
        let handles: Vec<_> = (0..num_cores().min(4))
            .map(|c| std::thread::spawn(move || pin_to_core(c)))
            .collect();
        for h in handles {
            // On Linux/x86_64 this should genuinely succeed; elsewhere the
            // no-op returns false. Either way joining must work.
            let _ = h.join().unwrap();
        }
    }

    #[test]
    fn interleave_does_not_crash() {
        let _ = interleave_allocations();
        let v: Vec<u64> = (0..10_000).collect();
        assert_eq!(v.len(), 10_000);
    }
}
