//! Support substrates: hashing, RNG + Zipf, JSON, clocks, summary
//! statistics, CLI parsing and a property-testing helper.
//!
//! Everything in here is hand-rolled because the offline build only has the
//! `xla` crate's dependency closure available; each piece carries its own
//! unit tests (hash against xxHash reference vectors, Zipf against
//! frequency-law checks, JSON against round-trips).

pub mod affinity;
pub mod check;
pub mod cli;
pub mod clock;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
