//! A small property-testing harness (proptest is not available offline).
//!
//! `check(name, cases, |rng| ...)` runs a property under many independently
//! seeded RNGs and reports the failing seed so any counterexample can be
//! replayed with `replay(seed, prop)`. Used for the cache invariants
//! (occupancy bounds, no phantom keys, model equivalence) in module tests
//! and `rust/tests/`.

use super::rng::Rng;

/// Base seed: fixed so CI is deterministic; override with KWAY_CHECK_SEED.
fn base_seed() -> u64 {
    std::env::var("KWAY_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_0001)
}

/// Run `prop` for `cases` independently seeded cases; panics with the seed
/// on the first failure (propagating the property's own panic message).
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed on case {case} (replay with \
                 KWAY_CHECK_SEED-independent seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single property case with an explicit seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_quietly() {
        check("add-commutes", 50, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_rng| {
                panic!("intentional");
            });
        });
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("always-fails"), "msg: {msg}");
        assert!(msg.contains("intentional"), "msg: {msg}");
        assert!(msg.contains("seed"), "msg: {msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = Vec::new();
        replay(42, |rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        replay(42, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
