//! Hashing: xxHash64 (the hash the paper uses to spread keys over sets),
//! plus cheap 64-bit finalizers for fingerprints.
//!
//! xxh64 is implemented from scratch (no external crates are available in
//! the offline build) and checked against the reference test vectors from
//! the xxHash specification.

const PRIME64_1: u64 = 0x9E3779B185EBCA87;
const PRIME64_2: u64 = 0xC2B2AE3D27D4EB4F;
const PRIME64_3: u64 = 0x165667B19E3779F9;
const PRIME64_4: u64 = 0x85EBCA77C2B2AE63;
const PRIME64_5: u64 = 0x27D4EB2F165667C5;

#[inline(always)]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME64_2))
        .rotate_left(31)
        .wrapping_mul(PRIME64_1)
}

#[inline(always)]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4)
}

#[inline(always)]
fn read_u64(b: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(b[i..i + 8].try_into().unwrap())
}

#[inline(always)]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(b[i..i + 4].try_into().unwrap())
}

/// xxHash64 of a byte slice.
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let len = data.len();
    let mut h: u64;
    let mut i = 0usize;

    if len >= 32 {
        let mut v1 = seed.wrapping_add(PRIME64_1).wrapping_add(PRIME64_2);
        let mut v2 = seed.wrapping_add(PRIME64_2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME64_1);
        while i + 32 <= len {
            v1 = round(v1, read_u64(data, i));
            v2 = round(v2, read_u64(data, i + 8));
            v3 = round(v3, read_u64(data, i + 16));
            v4 = round(v4, read_u64(data, i + 24));
            i += 32;
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(PRIME64_5);
    }

    h = h.wrapping_add(len as u64);

    while i + 8 <= len {
        h = (h ^ round(0, read_u64(data, i)))
            .rotate_left(27)
            .wrapping_mul(PRIME64_1)
            .wrapping_add(PRIME64_4);
        i += 8;
    }
    if i + 4 <= len {
        h = (h ^ (read_u32(data, i) as u64).wrapping_mul(PRIME64_1))
            .rotate_left(23)
            .wrapping_mul(PRIME64_2)
            .wrapping_add(PRIME64_3);
        i += 4;
    }
    while i < len {
        h = (h ^ (data[i] as u64).wrapping_mul(PRIME64_5))
            .rotate_left(11)
            .wrapping_mul(PRIME64_1);
        i += 1;
    }

    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^= h >> 32;
    h
}

/// xxHash64 of a `u64` key (little-endian bytes), the hot-path variant used
/// to map keys to sets. Specialized so it fully inlines with no loop.
#[inline(always)]
pub fn xxh64_u64(key: u64, seed: u64) -> u64 {
    let mut h = seed.wrapping_add(PRIME64_5).wrapping_add(8);
    h = (h ^ round(0, key)).rotate_left(27).wrapping_mul(PRIME64_1).wrapping_add(PRIME64_4);
    h ^= h >> 33;
    h = h.wrapping_mul(PRIME64_2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME64_3);
    h ^= h >> 32;
    h
}

/// SplitMix64 finalizer: a fast high-quality 64→64 mix, used to derive
/// fingerprints so they are independent of the set-index hash.
#[inline(always)]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The full 64-bit set hash of a key. Exposed separately from
/// [`set_index`] so the elastic-resize path can derive a key's set index
/// under *two* geometries (old and new set count) from one hash pass:
/// for any power-of-two `num_sets`, `set_hash(key) & (num_sets - 1)` is
/// the set index, and doubling `num_sets` splits set `s` into `s` and
/// `s + num_sets` — classic linear hashing.
#[inline(always)]
pub fn set_hash(key: u64) -> u64 {
    xxh64_u64(key, 0)
}

/// Map a key to a set index. `num_sets` must be a power of two (mirrors
/// `hash(key) & (numberOfSets-1)` in the paper's Algorithms 2–9).
#[inline(always)]
pub fn set_index(key: u64, num_sets: usize) -> usize {
    debug_assert!(num_sets.is_power_of_two());
    (set_hash(key) as usize) & (num_sets - 1)
}

/// Non-zero fingerprint for a key (0 is the empty-slot sentinel in WFSC).
#[inline(always)]
pub fn fingerprint(key: u64) -> u64 {
    mix64(key) | 1
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vectors from the xxHash specification / reference impl.
    #[test]
    fn xxh64_reference_vectors() {
        assert_eq!(xxh64(b"", 0), 0xEF46DB3751D8E999);
        assert_eq!(xxh64(b"a", 0), 0xD24EC4F1A98C6E5B);
        assert_eq!(xxh64(b"abc", 0), 0x44BC2CF5AD770999);
        assert_eq!(xxh64(b"abcd", 0), 0xDE0327B0D25D92CC);
        // Long input exercises the 32-byte stripe loop.
        let s = b"xxhash is an extremely fast non-cryptographic hash algorithm";
        assert_eq!(xxh64(s, 0), xxh64(s, 0));
        assert_ne!(xxh64(s, 0), xxh64(s, 1));
    }

    #[test]
    fn xxh64_u64_matches_general() {
        for key in [0u64, 1, 42, u64::MAX, 0xDEADBEEF] {
            for seed in [0u64, 7, 0xFFFF_FFFF_0000_0001] {
                assert_eq!(xxh64_u64(key, seed), xxh64(&key.to_le_bytes(), seed));
            }
        }
    }

    #[test]
    fn set_index_in_range_and_spread() {
        let num_sets = 256;
        let mut counts = vec![0usize; num_sets];
        for key in 0..100_000u64 {
            let s = set_index(key, num_sets);
            assert!(s < num_sets);
            counts[s] += 1;
        }
        let expect = 100_000 / num_sets;
        // Every set should be within 3x of uniform for sequential keys.
        for &c in &counts {
            assert!(c > expect / 3 && c < expect * 3, "skewed set load {c} vs {expect}");
        }
    }

    #[test]
    fn set_hash_splits_linearly_on_doubling() {
        // Doubling the set count must split set `s` into `s` and
        // `s + old_num_sets` — the property elastic resizing leans on.
        for key in 0..10_000u64 {
            let h = set_hash(key) as usize;
            let small = h & (128 - 1);
            let big = h & (256 - 1);
            assert!(big == small || big == small + 128, "key {key}: {small} -> {big}");
            assert_eq!(set_index(key, 128), small);
            assert_eq!(set_index(key, 256), big);
        }
    }

    #[test]
    fn fingerprint_never_zero() {
        for key in 0..10_000u64 {
            assert_ne!(fingerprint(key), 0);
        }
    }

    #[test]
    fn mix64_bijective_smoke() {
        // mix64 is a bijection; distinct inputs must give distinct outputs.
        let mut seen = std::collections::HashSet::new();
        for key in 0..10_000u64 {
            assert!(seen.insert(mix64(key)));
        }
    }
}
