//! Clocks: a shared logical clock (the `AtomicLong time` of the paper's
//! Algorithm 1, used by the LRU/Hyperbolic policies), a tiny wall-clock
//! timer for the benchmark harness, and a raw CPU cycle counter so the
//! hot-path benches can report cycles-per-op alongside ns-per-op.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotone logical clock shared by all sets of a cache. LRU policies
/// stamp entries with `tick()`; Hyperbolic divides access counts by the
/// logical age derived from it.
#[derive(Debug, Default)]
pub struct LogicalClock {
    now: AtomicU64,
}

impl LogicalClock {
    /// A fresh clock starting at 1 (0 is the never-touched sentinel).
    pub fn new() -> Self {
        // Start at 1 so that "0" can serve as the never-touched sentinel.
        Self { now: AtomicU64::new(1) }
    }

    /// Advance and return the new timestamp.
    #[inline]
    pub fn tick(&self) -> u64 {
        self.now.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Read without advancing.
    #[inline]
    pub fn now(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

/// Wall-clock stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Nanoseconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

/// Whether [`cycles_now`] returns a real CPU cycle counter on this
/// target (x86_64 `rdtsc`). When false, cycle figures are reported as 0
/// and the benches print only ns/op.
#[inline]
pub fn cycles_supported() -> bool {
    cfg!(target_arch = "x86_64")
}

/// Raw timestamp-counter read. On x86_64 this is `rdtsc` — a monotone
/// per-socket counter ticking at a constant (base) frequency on every
/// CPU of the last ~15 years, which is exactly what a cycles-per-op
/// figure wants: unlike ns/op it is invariant under frequency scaling of
/// the *measurement* clock. Cross-thread deltas are meaningful on the
/// same socket (the benches sum per-thread deltas, never subtract across
/// threads). Returns 0 where unsupported (see [`cycles_supported`]).
#[inline]
pub fn cycles_now() -> u64 {
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: rdtsc has no preconditions; it only reads the TSC.
        unsafe { core::arch::x86_64::_rdtsc() }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_monotone_and_start_past_zero() {
        let c = LogicalClock::new();
        let a = c.tick();
        let b = c.tick();
        assert!(a >= 2);
        assert!(b > a);
        assert_eq!(c.now(), b);
    }

    #[test]
    fn concurrent_ticks_unique() {
        let c = std::sync::Arc::new(LogicalClock::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| c.tick()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), before, "logical timestamps must be unique");
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_secs() > 0.0);
        assert!(sw.elapsed_nanos() > 0);
    }

    #[test]
    fn cycles_monotone_where_supported() {
        if !cycles_supported() {
            assert_eq!(cycles_now(), 0);
            return;
        }
        let a = cycles_now();
        // Burn a few thousand cycles so the counter visibly advances.
        let mut acc = 0u64;
        for i in 0..10_000u64 {
            acc = acc.wrapping_add(i).rotate_left(7);
        }
        let b = cycles_now();
        assert!(acc != 1, "keep the loop alive");
        assert!(b > a, "tsc must advance: {a} -> {b}");
    }
}
