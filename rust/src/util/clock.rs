//! Clocks: a shared logical clock (the `AtomicLong time` of the paper's
//! Algorithm 1, used by the LRU/Hyperbolic policies) and a tiny wall-clock
//! timer for the benchmark harness.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotone logical clock shared by all sets of a cache. LRU policies
/// stamp entries with `tick()`; Hyperbolic divides access counts by the
/// logical age derived from it.
#[derive(Debug, Default)]
pub struct LogicalClock {
    now: AtomicU64,
}

impl LogicalClock {
    /// A fresh clock starting at 1 (0 is the never-touched sentinel).
    pub fn new() -> Self {
        // Start at 1 so that "0" can serve as the never-touched sentinel.
        Self { now: AtomicU64::new(1) }
    }

    /// Advance and return the new timestamp.
    #[inline]
    pub fn tick(&self) -> u64 {
        self.now.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Read without advancing.
    #[inline]
    pub fn now(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

/// Wall-clock stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Nanoseconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_monotone_and_start_past_zero() {
        let c = LogicalClock::new();
        let a = c.tick();
        let b = c.tick();
        assert!(a >= 2);
        assert!(b > a);
        assert_eq!(c.now(), b);
    }

    #[test]
    fn concurrent_ticks_unique() {
        let c = std::sync::Arc::new(LogicalClock::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| c.tick()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), before, "logical timestamps must be unique");
    }

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_secs() > 0.0);
        assert!(sw.elapsed_nanos() > 0);
    }
}
