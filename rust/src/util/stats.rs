//! Summary statistics for the repeated-run benchmark methodology
//! (§5.1.2 of the paper: each point is the mean over 11 runs), plus
//! the reservoir sampler the latency harnesses use to keep an unbiased
//! fixed-memory sample of per-op timings (SNIPPETS.md Snippet 3's
//! methodology: ~10K samples per thread for p50/p95/p99).

use crate::util::rng::Rng;

/// Running mean/variance via Welford's algorithm plus retained samples for
/// percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    /// An empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Summarize an iterator of samples.
    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for x in samples {
            s.add(x);
        }
        s
    }

    /// Add one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
        }
    }

    /// Smallest sample (`inf` when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (`-inf` when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted samples;
    /// `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = q / 100.0 * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        }
    }

    /// The 50th percentile.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Half-width of the 95% confidence interval for the mean
    /// (normal approximation — fine at n = 11 for reporting purposes).
    pub fn ci95(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        1.96 * self.stddev() / (self.samples.len() as f64).sqrt()
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean={:.3} ±{:.3} (n={}, min={:.3}, p50={:.3}, max={:.3})",
            self.mean(),
            self.ci95(),
            self.count(),
            self.min(),
            self.median(),
            self.max()
        )
    }
}

/// Classic reservoir sampler (Algorithm R) over `u64` observations.
///
/// After `seen` observations, each one is retained with probability
/// `cap / seen` — so the reservoir is a uniform random subset of the
/// whole stream regardless of its length, and percentiles computed
/// from it are unbiased no matter how the stream's tail differs from
/// its head. This replaces the fixed-stride latency sampler, whose
/// every-Nth cadence could alias against periodic contention patterns
/// and systematically miss (or over-count) the slow tail.
#[derive(Debug, Clone)]
pub struct Reservoir {
    samples: Vec<u64>,
    cap: usize,
    seen: u64,
    rng: Rng,
}

impl Reservoir {
    /// A reservoir keeping at most `cap` samples; `seed` makes runs
    /// reproducible.
    pub fn new(cap: usize, seed: u64) -> Self {
        assert!(cap > 0, "a zero-capacity reservoir keeps nothing");
        Self { samples: Vec::with_capacity(cap.min(1 << 16)), cap, seen: 0, rng: Rng::new(seed) }
    }

    /// Offer one observation.
    pub fn record(&mut self, value: u64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(value);
        } else {
            // Keep with probability cap/seen: replace a uniformly
            // random slot iff the random index lands inside the
            // reservoir.
            let idx = self.rng.below(self.seen);
            if (idx as usize) < self.cap {
                self.samples[idx as usize] = value;
            }
        }
    }

    /// Total observations offered (not just retained).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Number of retained samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The retained samples (unordered).
    pub fn samples(&self) -> &[u64] {
        &self.samples
    }

    /// Consume the reservoir, yielding its samples.
    pub fn into_samples(self) -> Vec<u64> {
        self.samples
    }
}

/// Nearest-rank percentile over integer samples (`q` in [0, 100]);
/// sorts `samples` in place. Returns 0 for an empty slice — callers
/// report zero-filled rows rather than poisoning JSON with NaN.
pub fn percentile_u64(samples: &mut [u64], q: f64) -> u64 {
    assert!((0.0..=100.0).contains(&q));
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    let n = samples.len();
    let rank = ((q / 100.0 * n as f64).ceil() as usize).max(1);
    samples[rank.min(n) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let s = Summary::from_samples([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic set is ~2.138.
        assert!((s.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(s.count(), 8);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_samples((1..=100).map(|x| x as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples([42.0]);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 42.0);
        assert_eq!(s.ci95(), 0.0);
    }

    #[test]
    fn empty_is_nan_percentile() {
        let s = Summary::new();
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn reservoir_fills_then_caps() {
        let mut r = Reservoir::new(100, 1);
        for v in 0..50u64 {
            r.record(v);
        }
        assert_eq!(r.len(), 50, "below cap everything is kept");
        for v in 50..10_000u64 {
            r.record(v);
        }
        assert_eq!(r.len(), 100, "reservoir never exceeds its cap");
        assert_eq!(r.seen(), 10_000);
    }

    #[test]
    fn reservoir_sample_is_unbiased() {
        // Stream 1..=10_000 through a 1_000-slot reservoir: the sample
        // mean must land near the stream mean (5000.5). A fixed-stride
        // sampler would pass this too, but a broken replacement rule
        // (e.g. always replacing, which biases toward the tail) fails.
        let mut r = Reservoir::new(1_000, 42);
        for v in 1..=10_000u64 {
            r.record(v);
        }
        let mean = r.samples().iter().sum::<u64>() as f64 / r.len() as f64;
        assert!(
            (mean - 5000.5).abs() < 500.0,
            "reservoir mean {mean} too far from stream mean 5000.5"
        );
        // And it must retain observations from both halves.
        assert!(r.samples().iter().any(|&v| v <= 2_500));
        assert!(r.samples().iter().any(|&v| v >= 7_500));
    }

    #[test]
    fn reservoir_seeds_are_reproducible() {
        let mut a = Reservoir::new(10, 7);
        let mut b = Reservoir::new(10, 7);
        for v in 0..1_000u64 {
            a.record(v);
            b.record(v);
        }
        assert_eq!(a.samples(), b.samples());
    }

    #[test]
    fn percentile_u64_nearest_rank_exactness() {
        let mut v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_u64(&mut v, 50.0), 50);
        assert_eq!(percentile_u64(&mut v, 99.0), 99);
        assert_eq!(percentile_u64(&mut v, 100.0), 100);
        assert_eq!(percentile_u64(&mut v, 0.0), 1);

        let mut single = vec![7u64];
        assert_eq!(percentile_u64(&mut single, 50.0), 7);
        assert_eq!(percentile_u64(&mut single, 99.0), 7);

        let mut empty: Vec<u64> = Vec::new();
        assert_eq!(percentile_u64(&mut empty, 99.0), 0);

        // Unsorted input is handled (the function sorts in place).
        let mut shuffled = vec![30u64, 10, 20];
        assert_eq!(percentile_u64(&mut shuffled, 50.0), 20);
    }
}
