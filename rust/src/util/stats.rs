//! Summary statistics for the repeated-run benchmark methodology
//! (§5.1.2 of the paper: each point is the mean over 11 runs).

/// Running mean/variance via Welford's algorithm plus retained samples for
/// percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    /// An empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Summarize an iterator of samples.
    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for x in samples {
            s.add(x);
        }
        s
    }

    /// Add one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
        }
    }

    /// Smallest sample (`inf` when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (`-inf` when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted samples;
    /// `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = q / 100.0 * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let w = rank - lo as f64;
            sorted[lo] * (1.0 - w) + sorted[hi] * w
        }
    }

    /// The 50th percentile.
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Half-width of the 95% confidence interval for the mean
    /// (normal approximation — fine at n = 11 for reporting purposes).
    pub fn ci95(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        1.96 * self.stddev() / (self.samples.len() as f64).sqrt()
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean={:.3} ±{:.3} (n={}, min={:.3}, p50={:.3}, max={:.3})",
            self.mean(),
            self.ci95(),
            self.count(),
            self.min(),
            self.median(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let s = Summary::from_samples([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic set is ~2.138.
        assert!((s.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(s.count(), 8);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_samples((1..=100).map(|x| x as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples([42.0]);
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.median(), 42.0);
        assert_eq!(s.ci95(), 0.0);
    }

    #[test]
    fn empty_is_nan_percentile() {
        let s = Summary::new();
        assert!(s.percentile(50.0).is_nan());
    }
}
