//! Entry lifetime (TTL) and weight: the shared vocabulary for the
//! expiration and weighted-capacity dimension of every cache layer.
//!
//! The paper's pitch is that limited associativity makes cache-management
//! schemes *simple* to parallelize — and expiration is the scheme where
//! that advantage is starkest. A fully-associative design needs a global
//! timer wheel or a background sweeper to find dead entries; with k-way
//! sets, expired-entry reclamation is a bounded per-set scan folded into
//! the probe the set engine already does (an expired line is simply the
//! victim of first resort). This module holds everything that dimension
//! shares:
//!
//! * [`EntryOpts`] — the per-insert options (`ttl`, `weight`) carried by
//!   [`crate::Cache::put_with`] and [`crate::Cache::put_batch_with`];
//! * the packed **life word** — per-entry expiry deadline (48 bits of
//!   coarse milliseconds) and weight (16 bits) in one `u64`, so the
//!   wait-free variants can publish lifetime metadata with a single
//!   atomic store under their existing claim/publish protocols;
//! * the coarse monotonic clock ([`now_ms`]) shared by every
//!   implementation, so deadlines from different caches compare;
//! * [`WeightDist`] — the deterministic per-key weight generators the
//!   workloads and CLI (`--weight-dist`) use for size-aware scenarios;
//! * [`parse_duration`] — the `--ttl 100ms` CLI parser.
//!
//! Design notes: DESIGN.md §Expiration and §Weighted capacity.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Bits of the life word holding the expiry deadline (coarse ms).
const EXPIRY_BITS: u32 = 48;
/// Mask of the expiry field: 2^48 ms ≈ 8 900 years of process uptime.
const EXPIRY_MASK: u64 = (1 << EXPIRY_BITS) - 1;
/// Expiry field value meaning "never expires".
pub(crate) const NO_EXPIRY: u64 = EXPIRY_MASK;
/// Largest weight a single entry can carry (the 16-bit field saturates).
pub const MAX_WEIGHT: u32 = u16::MAX as u32;

/// Per-insert entry options: time-to-live and weight.
///
/// The default (`ttl: None`, `weight: 1`) makes
/// [`crate::Cache::put_with`] behave exactly like [`crate::Cache::put`]:
/// an immortal, unit-weight entry. A `ttl` of zero produces an entry that
/// is already expired — readable never — which tests use for
/// deterministic expiry without sleeping.
///
/// ```
/// use kway::EntryOpts;
/// use std::time::Duration;
///
/// let opts = EntryOpts::default();
/// assert_eq!(opts.ttl, None);
/// assert_eq!(opts.weight, 1);
/// let opts = EntryOpts::ttl(Duration::from_millis(100)).weighted(3);
/// assert_eq!(opts.weight, 3);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntryOpts {
    /// Time-to-live from the moment of the insert; `None` = immortal.
    pub ttl: Option<Duration>,
    /// Weight units this entry consumes of the per-set weight budget
    /// (clamped to [`MAX_WEIGHT`] on storage). Weight 0 is allowed and
    /// consumes a way but no budget.
    pub weight: u32,
}

impl Default for EntryOpts {
    fn default() -> Self {
        Self { ttl: None, weight: 1 }
    }
}

impl EntryOpts {
    /// Immortal unit-weight entry — identical to a plain `put`.
    pub const IMMORTAL: EntryOpts = EntryOpts { ttl: None, weight: 1 };

    /// Unit-weight entry expiring `ttl` from now.
    pub fn ttl(ttl: Duration) -> Self {
        Self { ttl: Some(ttl), weight: 1 }
    }

    /// Immortal entry of the given weight.
    pub fn weight(weight: u32) -> Self {
        Self { ttl: None, weight }
    }

    /// Builder-style weight override.
    pub fn weighted(mut self, weight: u32) -> Self {
        self.weight = weight;
        self
    }

    /// True when these options are indistinguishable from a plain `put`.
    pub fn is_plain(&self) -> bool {
        self.ttl.is_none() && self.weight == 1
    }
}

/// One item of a lifetime-carrying batched insert
/// ([`crate::Cache::put_batch_with`]).
#[derive(Debug, Clone, Copy)]
pub struct BatchEntry {
    /// Key to insert.
    pub key: u64,
    /// Value to store.
    pub value: u64,
    /// Lifetime/weight options for this item.
    pub opts: EntryOpts,
}

impl BatchEntry {
    /// Convenience constructor.
    pub fn new(key: u64, value: u64, opts: EntryOpts) -> Self {
        Self { key, value, opts }
    }
}

/// Milliseconds since the process-wide epoch (first call). Coarse on
/// purpose: a 48-bit millisecond deadline packs next to a 16-bit weight
/// in one atomic word, and cache TTLs below a millisecond are noise.
#[inline]
pub fn now_ms() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Absolute expiry deadline (coarse ms) for an optional TTL taken now.
/// `None` maps to [`NO_EXPIRY`]; finite deadlines are clamped below it.
/// Sub-millisecond TTLs round *up* to one tick, so `--ttl 250us` means
/// "alive this millisecond" — only an explicit zero TTL is born expired.
#[inline]
pub(crate) fn deadline_ms(ttl: Option<Duration>, now: u64) -> u64 {
    match ttl {
        None => NO_EXPIRY,
        Some(ttl) => {
            let mut ms = ttl.as_millis().min(u64::MAX as u128) as u64;
            if ms == 0 && !ttl.is_zero() {
                ms = 1;
            }
            now.saturating_add(ms).min(NO_EXPIRY - 1)
        }
    }
}

/// Pack an expiry deadline and a weight into one life word.
#[inline]
pub(crate) fn pack_life(expiry_ms: u64, weight: u32) -> u64 {
    ((weight.min(MAX_WEIGHT) as u64) << EXPIRY_BITS) | (expiry_ms & EXPIRY_MASK)
}

/// Life word of an immortal unit-weight entry (what a plain `put` stores).
#[inline]
pub(crate) fn immortal_unit() -> u64 {
    pack_life(NO_EXPIRY, 1)
}

/// Life word for an insert with `opts` happening at `now` (coarse ms).
#[inline]
pub(crate) fn life_of(opts: &EntryOpts, now: u64) -> u64 {
    pack_life(deadline_ms(opts.ttl, now), opts.weight)
}

/// Expiry deadline field of a life word.
#[inline]
pub(crate) fn expiry_of(life: u64) -> u64 {
    life & EXPIRY_MASK
}

/// Weight field of a life word.
#[inline]
pub(crate) fn weight_of(life: u64) -> u64 {
    life >> EXPIRY_BITS
}

/// Is an entry with this life word expired at coarse time `now`?
/// [`NO_EXPIRY`] deadlines never are (the clock cannot reach 2^48-1 ms).
#[inline]
pub(crate) fn is_expired(life: u64, now: u64) -> bool {
    expiry_of(life) <= now && expiry_of(life) != NO_EXPIRY
}

/// Deterministic per-key weight distributions for size-aware workloads
/// (`--weight-dist` on the CLI; [`crate::throughput::FillSpec`] in the
/// harness). Weights are a pure function of the key, so every fill of a
/// given key costs the same budget no matter which thread or repeat
/// performs it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightDist {
    /// Every entry weighs 1 — byte-compatible with the unweighted world.
    #[default]
    Unit,
    /// Uniform weights in `1..=max`.
    Uniform {
        /// Largest weight drawn.
        max: u32,
    },
    /// Pareto-skewed weights in `1..=max` (most keys small, a heavy
    /// tail of large entries — the "wildly non-uniform sizes" shape of
    /// real object caches).
    Zipf {
        /// Cap on the heavy tail.
        max: u32,
    },
}

impl WeightDist {
    /// Parse a CLI spelling: `unit`, `uniform[:MAX]`, `zipf[:MAX]`
    /// (default MAX = 8).
    pub fn parse(s: &str) -> Option<WeightDist> {
        let (name, max) = match s.split_once(':') {
            Some((n, m)) => (n, m.parse::<u32>().ok()?),
            None => (s, 8),
        };
        if max == 0 || max > MAX_WEIGHT {
            return None;
        }
        match name.to_ascii_lowercase().as_str() {
            "unit" | "none" => Some(WeightDist::Unit),
            "uniform" => Some(WeightDist::Uniform { max }),
            "zipf" | "pareto" => Some(WeightDist::Zipf { max }),
            _ => None,
        }
    }

    /// Canonical CLI spelling (inverse of [`WeightDist::parse`]).
    pub fn name(&self) -> String {
        match self {
            WeightDist::Unit => "unit".into(),
            WeightDist::Uniform { max } => format!("uniform:{max}"),
            WeightDist::Zipf { max } => format!("zipf:{max}"),
        }
    }

    /// The weight of `key` under this distribution (deterministic).
    pub fn weight_of(&self, key: u64) -> u32 {
        match self {
            WeightDist::Unit => 1,
            WeightDist::Uniform { max } => {
                1 + (crate::util::hash::mix64(key ^ 0xD15E_A5E1) % *max as u64) as u32
            }
            WeightDist::Zipf { max } => {
                // Pareto(α = 2) via inverse transform: P(w ≥ x) = x⁻²,
                // so most keys weigh 1 and a heavy tail reaches `max`.
                let h = crate::util::hash::mix64(key ^ 0x5EED_F00D);
                let u = (h >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
                let w = (1.0 - u).powf(-0.5);
                (w as u32).clamp(1, *max)
            }
        }
    }
}

/// Deterministic per-key value-*length* distributions for byte-value
/// workloads (`--value-dist` on the CLI; the slab bench and loadgen).
/// Like [`WeightDist`], lengths are a pure function of the key, so the
/// payload a key carries — and therefore the slab class it lands in —
/// is identical across threads, repeats and processes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ValueDist {
    /// Word values only — the byte path stays disabled.
    #[default]
    Word,
    /// Every value is exactly `len` bytes.
    Fixed {
        /// Payload length in bytes.
        len: u32,
    },
    /// Uniform lengths in `1..=max` — exercises every slab class below
    /// `max` about equally.
    Uniform {
        /// Largest length drawn.
        max: u32,
    },
    /// Pareto-skewed lengths in `1..=max` (most values small, a heavy
    /// tail of large blobs — the size shape of real object caches).
    Zipf {
        /// Cap on the heavy tail.
        max: u32,
    },
}

impl ValueDist {
    /// Parse a CLI spelling: `word`, `fixed:N`, `uniform:MAX`,
    /// `zipf:MAX` (default N/MAX = 128).
    pub fn parse(s: &str) -> Option<ValueDist> {
        let (name, n) = match s.split_once(':') {
            Some((n, m)) => (n, m.parse::<u32>().ok()?),
            None => (s, 128),
        };
        let name = name.to_ascii_lowercase();
        if name == "word" || name == "none" {
            return Some(ValueDist::Word);
        }
        if n == 0 {
            return None;
        }
        match name.as_str() {
            "fixed" => Some(ValueDist::Fixed { len: n }),
            "uniform" => Some(ValueDist::Uniform { max: n }),
            "zipf" | "pareto" => Some(ValueDist::Zipf { max: n }),
            _ => None,
        }
    }

    /// Canonical CLI spelling (inverse of [`ValueDist::parse`]).
    pub fn name(&self) -> String {
        match self {
            ValueDist::Word => "word".into(),
            ValueDist::Fixed { len } => format!("fixed:{len}"),
            ValueDist::Uniform { max } => format!("uniform:{max}"),
            ValueDist::Zipf { max } => format!("zipf:{max}"),
        }
    }

    /// Whether this distribution produces byte values at all.
    pub fn is_bytes(&self) -> bool {
        !matches!(self, ValueDist::Word)
    }

    /// The largest length this distribution can produce (0 for `Word`).
    pub fn max_len(&self) -> usize {
        match self {
            ValueDist::Word => 0,
            ValueDist::Fixed { len } => *len as usize,
            ValueDist::Uniform { max } | ValueDist::Zipf { max } => *max as usize,
        }
    }

    /// The value length of `key` under this distribution (deterministic;
    /// 0 for `Word`).
    pub fn len_of(&self, key: u64) -> usize {
        match self {
            ValueDist::Word => 0,
            ValueDist::Fixed { len } => *len as usize,
            ValueDist::Uniform { max } => {
                1 + (crate::util::hash::mix64(key ^ 0xB10B_517E) % *max as u64) as usize
            }
            ValueDist::Zipf { max } => {
                // Pareto(α = 1) via inverse transform, clamped: most keys
                // draw small blobs, the tail reaches `max` fast enough to
                // touch the top slab classes in a short run.
                let h = crate::util::hash::mix64(key ^ 0x0B1A_B10B);
                let u = (h >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
                let len = 1.0 / (1.0 - u);
                (len as u64).clamp(1, *max as u64) as usize
            }
        }
    }

    /// Fill `buf` with the deterministic payload of `key`: the drawn
    /// length, every byte derived from the key (so a torture test can
    /// verify a returned blob really belongs to the key it asked for).
    pub fn fill(&self, key: u64, buf: &mut Vec<u8>) {
        buf.clear();
        let len = self.len_of(key);
        buf.reserve(len);
        let mut word = crate::util::hash::mix64(key ^ 0xF1_11_ED);
        for i in 0..len {
            if i % 8 == 0 {
                word = crate::util::hash::mix64(word.wrapping_add(i as u64));
            }
            buf.push((word >> ((i % 8) * 8)) as u8);
        }
    }
}

/// Parse a human duration: `0`, `250us`, `100ms`, `2s`, `5m` (bare
/// numbers are milliseconds). Used by the `--ttl` CLI option.
pub fn parse_duration(s: &str) -> Option<Duration> {
    let s = s.trim();
    let (digits, unit) = match s.find(|c: char| !c.is_ascii_digit()) {
        Some(split) => s.split_at(split),
        None => (s, "ms"),
    };
    let n: u64 = digits.parse().ok()?;
    match unit.trim() {
        "us" | "µs" => Some(Duration::from_micros(n)),
        "ms" | "" => Some(Duration::from_millis(n)),
        "s" => Some(Duration::from_secs(n)),
        "m" | "min" => n.checked_mul(60).map(Duration::from_secs),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_are_plain_and_immortal() {
        assert!(EntryOpts::default().is_plain());
        assert_eq!(EntryOpts::default(), EntryOpts::IMMORTAL);
        assert!(!EntryOpts::ttl(Duration::from_millis(5)).is_plain());
        assert!(!EntryOpts::weight(3).is_plain());
        assert!(EntryOpts::weight(1).is_plain());
    }

    #[test]
    fn life_word_round_trips() {
        for (exp, w) in [(0u64, 0u32), (123, 1), (NO_EXPIRY, 7), (NO_EXPIRY - 1, 65535)] {
            let life = pack_life(exp, w);
            assert_eq!(expiry_of(life), exp);
            assert_eq!(weight_of(life), w as u64);
        }
        // Weight saturates at the 16-bit field.
        assert_eq!(weight_of(pack_life(0, u32::MAX)), MAX_WEIGHT as u64);
    }

    #[test]
    fn immortal_entries_never_expire() {
        let life = immortal_unit();
        assert!(!is_expired(life, 0));
        assert!(!is_expired(life, NO_EXPIRY - 1));
        assert_eq!(weight_of(life), 1);
    }

    #[test]
    fn zero_ttl_is_expired_immediately() {
        let now = 1000;
        let life = life_of(&EntryOpts::ttl(Duration::ZERO), now);
        assert!(is_expired(life, now));
        let life = life_of(&EntryOpts::ttl(Duration::from_millis(5)), now);
        assert!(!is_expired(life, now));
        assert!(!is_expired(life, now + 4));
        assert!(is_expired(life, now + 5));
    }

    #[test]
    fn sub_millisecond_ttls_round_up_to_one_tick() {
        // `--ttl 250us` must not be born expired on a millisecond clock:
        // any non-zero TTL gets at least one tick of life.
        let now = 1000;
        let life = life_of(&EntryOpts::ttl(Duration::from_micros(250)), now);
        assert!(!is_expired(life, now));
        assert_eq!(expiry_of(life), now + 1);
        assert!(is_expired(life, now + 1));
    }

    #[test]
    fn huge_ttls_clamp_below_no_expiry() {
        let life = life_of(&EntryOpts::ttl(Duration::from_secs(u64::MAX / 2)), 5);
        assert_eq!(expiry_of(life), NO_EXPIRY - 1);
        assert!(!is_expired(life, 1_000_000));
    }

    #[test]
    fn now_ms_is_monotone() {
        let a = now_ms();
        let b = now_ms();
        assert!(b >= a);
    }

    #[test]
    fn weight_dist_parse_and_name_round_trip() {
        for spec in ["unit", "uniform:4", "zipf:16"] {
            let d = WeightDist::parse(spec).unwrap();
            assert_eq!(d.name(), spec);
        }
        assert_eq!(WeightDist::parse("zipf"), Some(WeightDist::Zipf { max: 8 }));
        assert_eq!(WeightDist::parse("uniform:0"), None);
        assert_eq!(WeightDist::parse("bogus"), None);
    }

    #[test]
    fn weights_are_deterministic_and_in_range() {
        for dist in [
            WeightDist::Unit,
            WeightDist::Uniform { max: 6 },
            WeightDist::Zipf { max: 16 },
        ] {
            for key in 0..2000u64 {
                let w = dist.weight_of(key);
                assert_eq!(w, dist.weight_of(key), "{dist:?} key {key} not deterministic");
                assert!((1..=16).contains(&w), "{dist:?} key {key} weight {w}");
            }
        }
    }

    #[test]
    fn zipf_weights_are_skewed_small() {
        let dist = WeightDist::Zipf { max: 64 };
        let small = (0..10_000u64).filter(|&k| dist.weight_of(k) <= 2).count();
        // Pareto(2): P(w ≤ 2) = 1 - 1/4 = 0.75.
        assert!(small > 6_500, "only {small}/10000 small weights");
        let heavy = (0..10_000u64).filter(|&k| dist.weight_of(k) >= 8).count();
        assert!(heavy > 20, "no heavy tail: {heavy}");
    }

    #[test]
    fn value_dist_parse_and_name_round_trip() {
        for spec in ["word", "fixed:64", "uniform:4096", "zipf:1048576"] {
            let d = ValueDist::parse(spec).unwrap();
            assert_eq!(d.name(), spec);
        }
        assert_eq!(ValueDist::parse("fixed"), Some(ValueDist::Fixed { len: 128 }));
        assert_eq!(ValueDist::parse("none"), Some(ValueDist::Word));
        assert_eq!(ValueDist::parse("fixed:0"), None);
        assert_eq!(ValueDist::parse("bogus"), None);
    }

    #[test]
    fn value_lengths_are_deterministic_and_in_range() {
        for dist in [
            ValueDist::Fixed { len: 100 },
            ValueDist::Uniform { max: 500 },
            ValueDist::Zipf { max: 500 },
        ] {
            for key in 0..2000u64 {
                let len = dist.len_of(key);
                assert_eq!(len, dist.len_of(key), "{dist:?} key {key} not deterministic");
                assert!((1..=500).contains(&len), "{dist:?} key {key} len {len}");
            }
        }
        assert_eq!(ValueDist::Word.len_of(7), 0);
        assert!(!ValueDist::Word.is_bytes());
        assert!(ValueDist::Fixed { len: 1 }.is_bytes());
    }

    #[test]
    fn value_fill_is_key_stamped() {
        let dist = ValueDist::Uniform { max: 300 };
        let (mut a, mut b) = (Vec::new(), Vec::new());
        dist.fill(1, &mut a);
        dist.fill(1, &mut b);
        assert_eq!(a, b, "same key, same payload");
        dist.fill(2, &mut b);
        assert_ne!(a, b, "different keys draw different payloads");
        assert_eq!(a.len(), dist.len_of(1));
    }

    #[test]
    fn zipf_value_lengths_span_the_classes() {
        let dist = ValueDist::Zipf { max: 1 << 20 };
        let small = (0..10_000u64).filter(|&k| dist.len_of(k) <= 64).count();
        assert!(small > 8_000, "only {small}/10000 small blobs");
        let big = (0..10_000u64).filter(|&k| dist.len_of(k) >= 4096).count();
        assert!(big > 0, "no heavy tail");
    }

    #[test]
    fn duration_parser_accepts_cli_spellings() {
        assert_eq!(parse_duration("100ms"), Some(Duration::from_millis(100)));
        assert_eq!(parse_duration("2s"), Some(Duration::from_secs(2)));
        assert_eq!(parse_duration("250us"), Some(Duration::from_micros(250)));
        assert_eq!(parse_duration("5m"), Some(Duration::from_secs(300)));
        assert_eq!(parse_duration("0"), Some(Duration::ZERO));
        assert_eq!(parse_duration("42"), Some(Duration::from_millis(42)));
        assert_eq!(parse_duration("nope"), None);
        assert_eq!(parse_duration("10parsecs"), None);
        // Overflowing minute counts are rejected, not wrapped.
        assert_eq!(parse_duration("307445734561825861m"), None);
    }
}
