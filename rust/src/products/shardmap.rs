//! A fixed-capacity concurrent hash map with **lock-free reads** and
//! shard-locked writes — the stand-in for Java's `ConcurrentHashMap` that
//! Guava and Caffeine build on. Getting the read path lock-free matters
//! for reproducing Figures 28–29, where the paper shows Caffeine's bare
//! map reads beating every scan-based design at 100% hit ratio.
//!
//! Open addressing with linear probing; deletes leave tombstones.
//! Capacity is fixed at construction (bounded caches never grow), sized
//! with enough slack that the probe chains stay short.

use crate::util::hash;
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

const EMPTY: u64 = 0;
const TOMBSTONE: u64 = 1;
const OFFSET: u64 = 2;

struct Shard {
    /// Serializes writers within the shard; readers never take it.
    write_lock: Mutex<()>,
    keys: Box<[AtomicU64]>,
    values: Box<[AtomicU64]>,
    len: AtomicUsize,
    /// Tombstones currently in the table; when they exceed a quarter of
    /// the slots the next insert purges the shard (rebuild in place).
    tombs: AtomicUsize,
    mask: usize,
}

impl Shard {
    fn new(slots: usize) -> Self {
        Self {
            write_lock: Mutex::new(()),
            keys: (0..slots).map(|_| AtomicU64::new(EMPTY)).collect(),
            values: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            len: AtomicUsize::new(0),
            tombs: AtomicUsize::new(0),
            mask: slots - 1,
        }
    }

    /// Rebuild the shard without tombstones (caller holds `write_lock`).
    /// Lock-free readers racing the purge may see a transient false miss
    /// for a key that is being relocated — acceptable for a cache (a
    /// false miss is a spurious re-fetch, never a wrong value), and it
    /// keeps probe chains short under sustained churn, which dominates
    /// the miss-path cost otherwise.
    fn purge(&self) {
        let n = self.mask + 1;
        let mut live: Vec<(u64, u64)> = Vec::with_capacity(self.len.load(Ordering::Relaxed));
        for i in 0..n {
            let k = self.keys[i].load(Ordering::Relaxed);
            if k >= OFFSET {
                live.push((k, self.values[i].load(Ordering::Relaxed)));
            }
            self.keys[i].store(EMPTY, Ordering::Release);
        }
        for (ik, v) in live {
            let start = (hash::xxh64_u64(ik - OFFSET, 0x5AAD) >> 32) as usize & self.mask;
            for i in 0..n {
                let idx = (start + i) & self.mask;
                if self.keys[idx].load(Ordering::Relaxed) == EMPTY {
                    self.values[idx].store(v, Ordering::Release);
                    self.keys[idx].store(ik, Ordering::Release);
                    break;
                }
            }
        }
        self.tombs.store(0, Ordering::Release);
    }
}

/// Sharded open-addressing concurrent map `u64 -> u64`.
pub struct ShardMap {
    shards: Box<[CachePadded<Shard>]>,
    shard_mask: usize,
}

impl ShardMap {
    /// A map that can hold `expected_max` entries across `shards` shards
    /// (both rounded up to powers of two) with ~2.5x slot slack.
    pub fn new(expected_max: usize, shards: usize) -> Self {
        let nshards = shards.next_power_of_two();
        let slots = ((expected_max * 5 / 2) / nshards + 8).next_power_of_two();
        Self {
            shards: (0..nshards).map(|_| CachePadded::new(Shard::new(slots))).collect(),
            shard_mask: nshards - 1,
        }
    }

    #[inline]
    fn locate(&self, key: u64) -> (&Shard, usize) {
        let h = hash::xxh64_u64(key, 0x5AAD);
        let shard = &self.shards[(h as usize) & self.shard_mask];
        let slot = ((h >> 32) as usize) & shard.mask;
        (shard, slot)
    }

    /// Lock-free read.
    #[inline]
    pub fn get(&self, key: u64) -> Option<u64> {
        let ik = key + OFFSET;
        let (shard, start) = self.locate(key);
        let n = shard.mask + 1;
        for i in 0..n {
            let idx = (start + i) & shard.mask;
            let k = shard.keys[idx].load(Ordering::Acquire);
            if k == ik {
                let v = shard.values[idx].load(Ordering::Acquire);
                // Re-validate: a concurrent remove+reuse may have replaced
                // the slot while we read the value.
                if shard.keys[idx].load(Ordering::Acquire) == ik {
                    return Some(v);
                }
                // Restart the probe: the chain mutated under us.
                return self.get(key);
            }
            if k == EMPTY {
                return None;
            }
        }
        None
    }

    /// Insert or overwrite; returns true when the key was newly inserted.
    pub fn insert(&self, key: u64, value: u64) -> bool {
        let ik = key + OFFSET;
        let (shard, start) = self.locate(key);
        let _guard = shard.write_lock.lock().unwrap();
        if shard.tombs.load(Ordering::Relaxed) > (shard.mask + 1) / 4 {
            shard.purge();
        }
        let n = shard.mask + 1;
        let mut tomb: Option<usize> = None;
        for i in 0..n {
            let idx = (start + i) & shard.mask;
            let k = shard.keys[idx].load(Ordering::Relaxed);
            if k == ik {
                shard.values[idx].store(value, Ordering::Release);
                return false;
            }
            if k == TOMBSTONE && tomb.is_none() {
                tomb = Some(idx);
            }
            if k == EMPTY {
                let reused = tomb.is_some();
                let idx = tomb.unwrap_or(idx);
                if reused {
                    shard.tombs.fetch_sub(1, Ordering::Relaxed);
                }
                // Publish value before key so lock-free readers that match
                // the key always see a valid value.
                shard.values[idx].store(value, Ordering::Release);
                shard.keys[idx].store(ik, Ordering::Release);
                shard.len.fetch_add(1, Ordering::Relaxed);
                return true;
            }
        }
        // No EMPTY found; reuse a tombstone if we saw one.
        if let Some(idx) = tomb {
            shard.tombs.fetch_sub(1, Ordering::Relaxed);
            shard.values[idx].store(value, Ordering::Release);
            shard.keys[idx].store(ik, Ordering::Release);
            shard.len.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        panic!("ShardMap shard full: sized for fewer entries than inserted");
    }

    /// Remove; returns true when the key was present.
    pub fn remove(&self, key: u64) -> bool {
        let ik = key + OFFSET;
        let (shard, start) = self.locate(key);
        let _guard = shard.write_lock.lock().unwrap();
        let n = shard.mask + 1;
        for i in 0..n {
            let idx = (start + i) & shard.mask;
            let k = shard.keys[idx].load(Ordering::Relaxed);
            if k == ik {
                shard.keys[idx].store(TOMBSTONE, Ordering::Release);
                shard.len.fetch_sub(1, Ordering::Relaxed);
                shard.tombs.fetch_add(1, Ordering::Relaxed);
                return true;
            }
            if k == EMPTY {
                return false;
            }
        }
        false
    }

    /// Entry count (exact when quiescent).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len.load(Ordering::Relaxed)).sum()
    }

    /// True when no entries are present.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn insert_get_remove() {
        let m = ShardMap::new(1024, 4);
        assert_eq!(m.get(5), None);
        assert!(m.insert(5, 50));
        assert!(!m.insert(5, 51)); // overwrite
        assert_eq!(m.get(5), Some(51));
        assert!(m.remove(5));
        assert!(!m.remove(5));
        assert_eq!(m.get(5), None);
        assert_eq!(m.len(), 0);
    }

    #[test]
    fn tombstone_reuse_keeps_chains_findable() {
        let m = ShardMap::new(64, 1);
        for k in 0..32u64 {
            m.insert(k, k);
        }
        for k in (0..32u64).step_by(2) {
            m.remove(k);
        }
        for k in 32..48u64 {
            m.insert(k, k);
        }
        for k in (1..32u64).step_by(2) {
            assert_eq!(m.get(k), Some(k), "odd key {k} lost after tombstone churn");
        }
        for k in 32..48u64 {
            assert_eq!(m.get(k), Some(k));
        }
    }

    #[test]
    fn key_zero_and_one_supported() {
        // Internal sentinels must not clash with user keys 0/1.
        let m = ShardMap::new(16, 1);
        m.insert(0, 100);
        m.insert(1, 101);
        assert_eq!(m.get(0), Some(100));
        assert_eq!(m.get(1), Some(101));
    }

    #[test]
    fn concurrent_readers_during_writes() {
        let m = Arc::new(ShardMap::new(4096, 8));
        for k in 0..1024u64 {
            m.insert(k, k * 2);
        }
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(t);
                for _ in 0..50_000 {
                    let k = rng.below(2048);
                    if rng.chance(0.2) {
                        m.insert(k, k * 2);
                    } else if rng.chance(0.1) {
                        m.remove(k);
                    } else if let Some(v) = m.get(k) {
                        assert_eq!(v, k * 2, "phantom for key {k}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "ShardMap shard full")]
    fn overfull_panics_loudly() {
        let m = ShardMap::new(4, 1);
        for k in 0..1000u64 {
            m.insert(k, k);
        }
    }
}
