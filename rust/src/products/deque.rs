//! An intrusive access-order deque (slab + key index) with explicit
//! operations — the building block for the Guava-like segments and the
//! Caffeine-like window/probation/protected regions. Unlike
//! [`crate::fully::LruList`] it never evicts by itself; region policies
//! decide when to pop.

use std::collections::HashMap;

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy)]
struct Node {
    key: u64,
    prev: u32,
    next: u32,
}

/// Access-order deque: front = most recently used, back = eviction end.
#[derive(Default)]
pub struct AccessDeque {
    map: HashMap<u64, u32>,
    nodes: Vec<Node>,
    head: u32,
    tail: u32,
    free: Vec<u32>,
}

impl AccessDeque {
    /// An empty deque.
    pub fn new() -> Self {
        Self { map: HashMap::new(), nodes: Vec::new(), head: NIL, tail: NIL, free: Vec::new() }
    }

    /// Number of linked keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no keys are linked.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Is `key` currently linked?
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    fn unlink(&mut self, idx: u32) {
        let node = self.nodes[idx as usize];
        match node.prev {
            NIL => self.head = node.next,
            p => self.nodes[p as usize].next = node.next,
        }
        match node.next {
            NIL => self.tail = node.prev,
            n => self.nodes[n as usize].prev = node.prev,
        }
    }

    fn link_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let node = &mut self.nodes[idx as usize];
            node.prev = NIL;
            node.next = old_head;
        }
        if old_head != NIL {
            self.nodes[old_head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Insert a new key at the MRU end. Panics if already present.
    pub fn push_front(&mut self, key: u64) {
        assert!(!self.map.contains_key(&key), "push_front of resident key");
        let idx = if let Some(idx) = self.free.pop() {
            self.nodes[idx as usize] = Node { key, prev: NIL, next: NIL };
            idx
        } else {
            self.nodes.push(Node { key, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        };
        self.link_front(idx);
        self.map.insert(key, idx);
    }

    /// Move an existing key to the MRU end; false if absent.
    pub fn touch(&mut self, key: u64) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            if self.head != idx {
                self.unlink(idx);
                self.link_front(idx);
            }
            true
        } else {
            false
        }
    }

    /// Remove a specific key; false if absent.
    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(idx) = self.map.remove(&key) {
            self.unlink(idx);
            self.free.push(idx);
            true
        } else {
            false
        }
    }

    /// Evict from the LRU end.
    pub fn pop_back(&mut self) -> Option<u64> {
        if self.tail == NIL {
            return None;
        }
        let idx = self.tail;
        let key = self.nodes[idx as usize].key;
        self.unlink(idx);
        self.map.remove(&key);
        self.free.push(idx);
        Some(key)
    }

    /// Peek the LRU end.
    pub fn back(&self) -> Option<u64> {
        (self.tail != NIL).then(|| self.nodes[self.tail as usize].key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_without_touch() {
        let mut d = AccessDeque::new();
        for k in 1..=3 {
            d.push_front(k);
        }
        assert_eq!(d.pop_back(), Some(1));
        assert_eq!(d.pop_back(), Some(2));
        assert_eq!(d.pop_back(), Some(3));
        assert_eq!(d.pop_back(), None);
    }

    #[test]
    fn touch_moves_to_front() {
        let mut d = AccessDeque::new();
        for k in 1..=3 {
            d.push_front(k);
        }
        assert!(d.touch(1));
        assert_eq!(d.back(), Some(2));
        assert!(!d.touch(99));
    }

    #[test]
    fn remove_and_reuse_slots() {
        let mut d = AccessDeque::new();
        for k in 1..=3 {
            d.push_front(k);
        }
        assert!(d.remove(2));
        assert!(!d.remove(2));
        assert_eq!(d.len(), 2);
        d.push_front(4); // reuses the freed slot
        assert_eq!(d.len(), 3);
        assert_eq!(d.pop_back(), Some(1));
        assert_eq!(d.pop_back(), Some(3));
        assert_eq!(d.pop_back(), Some(4));
    }

    #[test]
    #[should_panic(expected = "push_front of resident key")]
    fn duplicate_push_panics() {
        let mut d = AccessDeque::new();
        d.push_front(1);
        d.push_front(1);
    }
}
