//! `SegmentedCaffeine` — the paper's proof-of-concept comparator
//! ("segmented Caffeine", private communication with Ben Manes, §5.1):
//! N independent Caffeine instances, each sized `capacity / N`, with keys
//! routed by hash. Each instance keeps its own single drain thread, so
//! writes parallelize across segments at the possible cost of hit ratio —
//! which the paper (and our hit-ratio sim) finds to be nearly unchanged.

use super::caffeine_like::CaffeineLike;
use crate::util::hash;
use crate::Cache;

/// Hash-routed array of independent Caffeine-like caches.
pub struct SegmentedCaffeine {
    segments: Vec<CaffeineLike>,
    capacity: usize,
}

impl SegmentedCaffeine {
    /// The paper constructs each instance with `MAX_SIZE / #segments` and
    /// matches the segment count to the thread count tested.
    pub fn new(capacity: usize, segments: usize) -> Self {
        assert!(capacity > 0 && segments > 0);
        let nsegs = segments.next_power_of_two();
        let per = capacity.div_ceil(nsegs).max(1);
        Self {
            segments: (0..nsegs).map(|_| CaffeineLike::new(per)).collect(),
            capacity,
        }
    }

    /// Inline-policy variant for deterministic simulation (see
    /// [`CaffeineLike::new_inline`]).
    pub fn new_inline(capacity: usize, segments: usize) -> Self {
        assert!(capacity > 0 && segments > 0);
        let nsegs = segments.next_power_of_two();
        let per = capacity.div_ceil(nsegs).max(1);
        Self {
            segments: (0..nsegs).map(|_| CaffeineLike::new_inline(per)).collect(),
            capacity,
        }
    }

    #[inline]
    fn segment(&self, key: u64) -> &CaffeineLike {
        let idx = (hash::xxh64_u64(key, 0x5E6C) as usize) & (self.segments.len() - 1);
        &self.segments[idx]
    }

    /// Number of independent Caffeine segments.
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Wait for every segment's maintenance thread to catch up (used by
    /// the deterministic hit-ratio simulation).
    pub fn drain_sync_all(&self) {
        for seg in &self.segments {
            seg.drain_sync();
        }
    }
}

impl Cache for SegmentedCaffeine {
    fn get(&self, key: u64) -> Option<u64> {
        self.segment(key).get(key)
    }

    fn put(&self, key: u64, value: u64) {
        self.segment(key).put(key, value)
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.segments.iter().map(|s| s.len()).sum()
    }

    fn name(&self) -> &'static str {
        "segmented-Caffeine"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_and_round_trips() {
        let c = SegmentedCaffeine::new(512, 4);
        assert_eq!(c.segment_count(), 4);
        for k in 0..100u64 {
            c.put(k, k * 7);
        }
        for k in 0..100u64 {
            assert_eq!(c.get(k), Some(k * 7));
        }
    }

    #[test]
    fn capacity_is_total() {
        let c = SegmentedCaffeine::new(1024, 8);
        assert_eq!(c.capacity(), 1024);
    }
}
