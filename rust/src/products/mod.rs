//! Re-implementations of the production comparators from the paper's
//! evaluation (§5.1): Guava, Caffeine and segmented Caffeine.
//!
//! These are *architectural* re-implementations: the Java libraries'
//! behaviours that the paper's throughput analysis hinges on — Guava's
//! foreground per-segment eviction, Caffeine's single-threaded write-drain
//! with lossy read buffers, segmented Caffeine's hash routing — are
//! reproduced exactly; incidental engineering (weak references, expiry
//! timers, stats recording) is not.

mod caffeine_like;
mod shardmap;
mod deque;
mod guava_like;
mod segmented;

pub use caffeine_like::CaffeineLike;
pub use deque::AccessDeque;
pub use guava_like::GuavaLike;
pub use segmented::SegmentedCaffeine;
pub use shardmap::ShardMap;
