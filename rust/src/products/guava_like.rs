//! `GuavaLike` — a re-implementation of the architecture of Google Guava's
//! `LocalCache`:
//!
//! * the backing table is a `ConcurrentHashMap`-style map with
//!   **lock-free reads** ([`super::shardmap::ShardMap`]);
//! * each *segment* owns an LRU access queue guarded by one lock; reads
//!   record themselves into a lossy per-segment recency buffer (Guava's
//!   `recencyQueue`) that is drained into the access queue under the
//!   segment lock on writes;
//! * eviction happens *in the foreground*, inside the writing thread,
//!   under the segment lock.
//!
//! This is the behaviour the paper leans on to explain why "Guava is
//! considerably faster than Caffeine in traces with a significant number
//! of misses" (§5.3–§5.4): writers do their own eviction in parallel
//! across segments instead of funnelling through one drain thread, while
//! reads stay almost as cheap as bare map reads.

use super::deque::AccessDeque;
use super::shardmap::ShardMap;
use crate::util::hash;
use crate::Cache;
use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-segment lossy recency buffer length.
const RECENCY_RING: usize = 256;

struct SegInner {
    order: AccessDeque,
    /// Next ring position to drain; trails `ring_head` by at most the
    /// ring length (older events were overwritten/dropped, like Guava's
    /// lossy recencyQueue).
    cursor: u64,
}

struct Segment {
    inner: Mutex<SegInner>,
    ring: Box<[AtomicU64]>,
    ring_head: AtomicU64,
}

impl Segment {
    fn new() -> Self {
        Self {
            inner: Mutex::new(SegInner { order: AccessDeque::new(), cursor: 0 }),
            ring: (0..RECENCY_RING).map(|_| AtomicU64::new(0)).collect(),
            ring_head: AtomicU64::new(0),
        }
    }

    /// Record a read (lossy, like Guava's recencyQueue).
    #[inline]
    fn record_read(&self, key: u64) {
        let head = self.ring_head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.ring[(head as usize) % RECENCY_RING];
        let _ = slot.compare_exchange(0, key + 1, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Apply buffered recency to the access order (caller holds `inner`).
    /// Cursor-based: each put drains only the events recorded since the
    /// last drain (bounded by the ring length), not the whole ring.
    fn drain_ring(&self, inner: &mut SegInner) {
        let head = self.ring_head.load(Ordering::Acquire);
        let mut cur = inner.cursor.max(head.saturating_sub(RECENCY_RING as u64));
        while cur < head {
            let v = self.ring[(cur as usize) % RECENCY_RING].swap(0, Ordering::Relaxed);
            cur += 1;
            if v != 0 {
                inner.order.touch(v - 1);
            }
        }
        inner.cursor = cur;
    }
}

/// Segmented-LRU product baseline (Guava architecture).
pub struct GuavaLike {
    map: ShardMap,
    segments: Box<[CachePadded<Segment>]>,
    seg_capacity: usize,
    capacity: usize,
}

impl GuavaLike {
    /// Guava's default concurrency level is 4; the paper's throughput
    /// study exercises more threads, so the harness passes the thread
    /// count. Segment count is rounded to a power of two.
    pub fn new(capacity: usize, segments: usize) -> Self {
        assert!(capacity > 0 && segments > 0);
        let nsegs = segments.next_power_of_two();
        let seg_capacity = capacity.div_ceil(nsegs).max(1);
        Self {
            map: ShardMap::new(capacity + nsegs + 64, nsegs.max(16)),
            segments: (0..nsegs).map(|_| CachePadded::new(Segment::new())).collect(),
            seg_capacity,
            capacity,
        }
    }

    /// Default construction mirroring Guava's `concurrencyLevel(4)`.
    pub fn with_defaults(capacity: usize) -> Self {
        Self::new(capacity, 4)
    }

    #[inline]
    fn segment(&self, key: u64) -> &Segment {
        let idx = (hash::xxh64_u64(key, 0x6AA7A) as usize) & (self.segments.len() - 1);
        &self.segments[idx]
    }
}

impl Cache for GuavaLike {
    fn get(&self, key: u64) -> Option<u64> {
        // Lock-free map read + lossy recency recording.
        let value = self.map.get(key);
        if value.is_some() {
            self.segment(key).record_read(key);
        }
        value
    }

    fn put(&self, key: u64, value: u64) {
        let seg = self.segment(key);
        let mut inner = seg.inner.lock().unwrap();
        seg.drain_ring(&mut inner);
        let newly = self.map.insert(key, value);
        if newly {
            inner.order.push_front(key);
        } else {
            inner.order.touch(key);
        }
        // Foreground eviction under the segment lock — Guava's way.
        while inner.order.len() > self.seg_capacity {
            if let Some(victim) = inner.order.pop_back() {
                self.map.remove(victim);
            }
        }
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn name(&self) -> &'static str {
        "Guava-like"
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        let seg = self.segment(key);
        let inner = seg.inner.lock().unwrap();
        if inner.order.len() >= self.seg_capacity {
            inner.order.back()
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn put_get_overwrite() {
        let c = GuavaLike::new(64, 4);
        c.put(1, 10);
        assert_eq!(c.get(1), Some(10));
        c.put(1, 11);
        assert_eq!(c.get(1), Some(11));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn per_segment_lru_with_read_recency() {
        // Single segment: behaves as LRU with (drained) read recency.
        let c = GuavaLike::new(3, 1);
        c.put(1, 1);
        c.put(2, 2);
        c.put(3, 3);
        c.get(1); // recorded in the ring
        c.put(4, 4); // drains ring (1 becomes MRU), evicts 2
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(1), Some(1));
    }

    #[test]
    fn bounded_under_churn() {
        let c = GuavaLike::new(256, 8);
        for k in 0..100_000u64 {
            c.put(k, k);
        }
        assert!(c.len() <= c.capacity() + 8);
    }

    #[test]
    fn concurrent_smoke() {
        let c = Arc::new(GuavaLike::new(1024, 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(400 + t);
                for _ in 0..10_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.5) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= c.capacity() + 16);
    }
}
