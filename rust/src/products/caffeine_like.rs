//! `CaffeineLike` — a re-implementation of the architecture of Caffeine
//! (Ben Manes' W-TinyLFU cache), faithful to the properties the paper
//! measures against:
//!
//! * **Reads** are cheap map reads; the access is recorded into a lossy
//!   bounded *read buffer* (events are dropped when the buffer is full,
//!   exactly like Caffeine) and applied to the policy asynchronously.
//!   This is why "Caffeine is considerably faster than all alternatives"
//!   at 100% hit ratio (Figure 28).
//! * **Writes** insert into the map in the calling thread, then enqueue a
//!   write event into a *bounded write buffer* drained by **one**
//!   maintenance thread that runs the W-TinyLFU policy (window LRU →
//!   TinyLFU admission → probation/protected SLRU). When writers outrun
//!   the drain thread the write buffer fills and writers stall — the
//!   single-threaded put bottleneck the paper observes in Figures 14–30.
//!
//! The map itself is a `ConcurrentHashMap` stand-in with lock-free reads
//! and shard-locked writes (`super::shardmap::ShardMap`).

use super::deque::AccessDeque;
use super::shardmap::ShardMap;
use crate::tinylfu::FrequencySketch;
use crate::Cache;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

const SHARDS: usize = 64;
const READ_BUFFER: usize = 4096;
const READ_DRAIN_BATCH: usize = 512;
const WRITE_BUFFER: usize = 4096;

/// Where a key currently lives in the W-TinyLFU policy.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Region {
    Window,
    Probation,
    Protected,
}

/// Policy state owned exclusively by the maintenance thread.
struct PolicyState {
    sketch: FrequencySketch,
    region: HashMap<u64, Region>,
    window: AccessDeque,
    probation: AccessDeque,
    protected: AccessDeque,
    window_cap: usize,
    probation_cap: usize,
    protected_cap: usize,
}

impl PolicyState {
    fn new(capacity: usize) -> Self {
        // Caffeine defaults: 1% window, 99% main split 20/80
        // probation/protected.
        let window_cap = (capacity / 100).max(1);
        let main = capacity - window_cap;
        let protected_cap = (main * 4 / 5).max(1);
        let probation_cap = (main - protected_cap).max(1);
        Self {
            sketch: FrequencySketch::new(capacity),
            region: HashMap::with_capacity(capacity * 2),
            window: AccessDeque::new(),
            probation: AccessDeque::new(),
            protected: AccessDeque::new(),
            window_cap,
            probation_cap,
            protected_cap,
        }
    }

    /// Apply one read event.
    fn on_read(&mut self, key: u64) {
        self.sketch.record(key);
        match self.region.get(&key).copied() {
            Some(Region::Window) => {
                self.window.touch(key);
            }
            Some(Region::Probation) => {
                // Promote to protected.
                self.probation.remove(key);
                self.protected.push_front(key);
                self.region.insert(key, Region::Protected);
                while self.protected.len() > self.protected_cap {
                    if let Some(demoted) = self.protected.pop_back() {
                        self.probation.push_front(demoted);
                        self.region.insert(demoted, Region::Probation);
                    }
                }
            }
            Some(Region::Protected) => {
                self.protected.touch(key);
            }
            None => {}
        }
    }

    /// Apply one write (insertion) event; returns keys to evict from the
    /// backing map.
    fn on_write(&mut self, key: u64) -> Vec<u64> {
        self.sketch.record(key);
        if self.region.contains_key(&key) {
            // Value update of a resident key: treat as an access.
            self.on_read(key);
            return Vec::new();
        }
        self.window.push_front(key);
        self.region.insert(key, Region::Window);
        let mut evicted = Vec::new();
        // Overflow the window into the main space through admission.
        while self.window.len() > self.window_cap {
            let candidate = match self.window.pop_back() {
                Some(c) => c,
                None => break,
            };
            if self.probation.len() + self.protected.len()
                < self.probation_cap + self.protected_cap
            {
                self.probation.push_front(candidate);
                self.region.insert(candidate, Region::Probation);
                continue;
            }
            let victim = match self.probation.back().or_else(|| self.protected.back()) {
                Some(v) => v,
                None => {
                    self.probation.push_front(candidate);
                    self.region.insert(candidate, Region::Probation);
                    continue;
                }
            };
            if self.sketch.admit(candidate, victim) {
                // Candidate replaces the victim.
                if !self.probation.remove(victim) {
                    self.protected.remove(victim);
                }
                self.region.remove(&victim);
                evicted.push(victim);
                self.probation.push_front(candidate);
                self.region.insert(candidate, Region::Probation);
            } else {
                self.region.remove(&candidate);
                evicted.push(candidate);
            }
        }
        evicted
    }
}

/// Shared queues between callers and the maintenance thread.
struct Buffers {
    /// Lossy read ring: slots hold key+1 (0 = empty).
    read_ring: Box<[AtomicU64]>,
    read_head: AtomicU64,
    write_queue: Mutex<VecDeque<u64>>,
    work_ready: Condvar,
    shutdown: AtomicBool,
    /// Write events enqueued but not yet applied by the maintenance
    /// thread; lets callers (tests, the deterministic hit-ratio
    /// simulator) wait for the policy to catch up.
    pending_writes: AtomicU64,
    /// Read events sitting in the ring, not yet applied.
    pending_reads: AtomicU64,
}

struct Shared {
    /// `ConcurrentHashMap` stand-in: lock-free reads, shard-locked writes.
    map: ShardMap,
    buffers: Buffers,
}

/// W-TinyLFU product baseline (Caffeine architecture).
pub struct CaffeineLike {
    shared: Arc<Shared>,
    capacity: usize,
    drainer: Option<std::thread::JoinHandle<()>>,
    /// Inline mode: the policy is applied synchronously under a mutex in
    /// the caller thread instead of via buffers + drain thread. Used by
    /// the hit-ratio simulator (deterministic and fast); the throughput
    /// harness always uses the async mode, which is the architecture the
    /// paper measures.
    inline_policy: Option<Mutex<PolicyState>>,
}

impl CaffeineLike {
    /// Deterministic single-threaded variant for simulation.
    pub fn new_inline(capacity: usize) -> Self {
        assert!(capacity > 0);
        let shared = Arc::new(Shared {
            map: ShardMap::new(capacity + 64, SHARDS),
            buffers: Buffers {
                read_ring: Box::new([]),
                read_head: AtomicU64::new(0),
                write_queue: Mutex::new(VecDeque::new()),
                work_ready: Condvar::new(),
                shutdown: AtomicBool::new(false),
                pending_writes: AtomicU64::new(0),
                pending_reads: AtomicU64::new(0),
            },
        });
        Self {
            shared,
            capacity,
            drainer: None,
            inline_policy: Some(Mutex::new(PolicyState::new(capacity))),
        }
    }

    /// A Caffeine-like cache of `capacity` entries with a background
    /// maintenance (drain) thread, as the real library runs.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        let shared = Arc::new(Shared {
            map: ShardMap::new(capacity + WRITE_BUFFER + 1024, SHARDS),
            buffers: Buffers {
                read_ring: (0..READ_BUFFER).map(|_| AtomicU64::new(0)).collect(),
                read_head: AtomicU64::new(0),
                write_queue: Mutex::new(VecDeque::with_capacity(WRITE_BUFFER)),
                work_ready: Condvar::new(),
                shutdown: AtomicBool::new(false),
                pending_writes: AtomicU64::new(0),
                pending_reads: AtomicU64::new(0),
            },
        });
        let drainer = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("caffeine-drain".into())
                .spawn(move || Self::maintenance_loop(shared, capacity))
                .expect("spawn maintenance thread")
        };
        Self { shared, capacity, drainer: Some(drainer), inline_policy: None }
    }

    /// The single policy/maintenance thread (Caffeine's async drain).
    fn maintenance_loop(shared: Arc<Shared>, capacity: usize) {
        let mut policy = PolicyState::new(capacity);
        let mut read_cursor = 0usize;
        loop {
            // Drain pending write events (bounded batch per iteration).
            let batch: Vec<u64> = {
                let mut q = shared.buffers.write_queue.lock().unwrap();
                if q.is_empty() && shared.buffers.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if q.is_empty() && shared.buffers.pending_reads.load(Ordering::Acquire) == 0 {
                    // Sleep until work arrives (or shutdown). Reads that
                    // race in are caught by the timeout.
                    let (guard, _timeout) = shared
                        .buffers
                        .work_ready
                        .wait_timeout(q, std::time::Duration::from_millis(1))
                        .unwrap();
                    q = guard;
                }
                q.drain(..).collect()
            };
            for key in batch {
                for victim in policy.on_write(key) {
                    shared.map.remove(victim);
                }
                shared.buffers.pending_writes.fetch_sub(1, Ordering::Release);
            }
            // Drain the lossy read ring (bounded batch per iteration —
            // real Caffeine also samples reads rather than applying every
            // one; on this single-core testbed the cap keeps the policy
            // thread from starving the workload threads).
            for _ in 0..READ_DRAIN_BATCH {
                let slot = &shared.buffers.read_ring[read_cursor];
                let v = slot.swap(0, Ordering::Relaxed);
                read_cursor = (read_cursor + 1) % READ_BUFFER;
                if v == 0 {
                    break;
                }
                shared.buffers.pending_reads.fetch_sub(1, Ordering::Release);
                policy.on_read(v - 1);
            }
        }
    }

    /// Block until every write event enqueued so far has been applied by
    /// the maintenance thread. Used by tests and by the hit-ratio
    /// simulator, which needs the policy to be deterministic relative to
    /// the access stream.
    pub fn drain_sync(&self) {
        if self.inline_policy.is_some() {
            return; // inline mode is always caught up
        }
        while self.shared.buffers.pending_writes.load(Ordering::Acquire) != 0
            || self.shared.buffers.pending_reads.load(Ordering::Acquire) != 0
        {
            self.shared.buffers.work_ready.notify_one();
            std::thread::yield_now();
        }
    }

    /// Write events not yet applied by the maintenance thread.
    pub fn pending_writes(&self) -> u64 {
        self.shared.buffers.pending_writes.load(Ordering::Acquire)
    }

    /// Record a read event; lossy (dropped when the ring slot is taken).
    /// Deliberately minimal — one fetch_add and one CAS — because this is
    /// on the read hot path whose cheapness Figure 28 measures. The
    /// drainer picks the ring up on its own cadence.
    #[inline]
    fn record_read(&self, key: u64) {
        let head = self.shared.buffers.read_head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.shared.buffers.read_ring[(head as usize) % READ_BUFFER];
        // Only write into a free slot — otherwise drop, like Caffeine.
        if slot.compare_exchange(0, key + 1, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
            self.shared.buffers.pending_reads.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl Drop for CaffeineLike {
    fn drop(&mut self) {
        self.shared.buffers.shutdown.store(true, Ordering::Release);
        self.shared.buffers.work_ready.notify_all();
        if let Some(h) = self.drainer.take() {
            let _ = h.join();
        }
    }
}

impl Cache for CaffeineLike {
    fn get(&self, key: u64) -> Option<u64> {
        // Lock-free map read (the reason Caffeine dominates Figure 28).
        let value = self.shared.map.get(key);
        if value.is_some() {
            if let Some(policy) = &self.inline_policy {
                policy.lock().unwrap().on_read(key);
            } else {
                self.record_read(key);
            }
        }
        value
    }

    fn put(&self, key: u64, value: u64) {
        // Foreground: map insert (shard write lock, brief).
        self.shared.map.insert(key, value);
        if let Some(policy) = &self.inline_policy {
            let mut policy = policy.lock().unwrap();
            for victim in policy.on_write(key) {
                self.shared.map.remove(victim);
            }
            return;
        }
        // Policy work goes through the bounded write buffer; stall when
        // full (Caffeine applies backpressure the same way).
        loop {
            {
                let mut q = self.shared.buffers.write_queue.lock().unwrap();
                if q.len() < WRITE_BUFFER {
                    q.push_back(key);
                    self.shared.buffers.pending_writes.fetch_add(1, Ordering::Release);
                    break;
                }
            }
            self.shared.buffers.work_ready.notify_one();
            std::thread::yield_now();
        }
        self.shared.buffers.work_ready.notify_one();
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.shared.map.len()
    }

    fn name(&self) -> &'static str {
        "Caffeine-like"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc as StdArc;

    fn drain_wait(c: &CaffeineLike) {
        c.drain_sync();
    }

    #[test]
    fn put_get_roundtrip() {
        let c = CaffeineLike::new(128);
        c.put(1, 10);
        assert_eq!(c.get(1), Some(10));
        c.put(1, 11);
        assert_eq!(c.get(1), Some(11));
    }

    #[test]
    fn eventually_bounded() {
        let c = CaffeineLike::new(128);
        for k in 0..10_000u64 {
            c.put(k, k);
        }
        drain_wait(&c);
        // Transient overshoot is allowed (async drain); after draining the
        // resident set must be within capacity plus the in-flight window.
        assert!(
            c.len() <= 128 + 64,
            "len {} far exceeds capacity after drain",
            c.len()
        );
    }

    #[test]
    fn hot_keys_survive_scan() {
        let c = CaffeineLike::new(128);
        // Build frequency for a hot working set.
        for _ in 0..50 {
            for k in 0..64u64 {
                if c.get(k).is_none() {
                    c.put(k, k);
                }
            }
            drain_wait(&c);
        }
        // One-pass scan of cold keys.
        for k in 10_000..12_000u64 {
            if c.get(k).is_none() {
                c.put(k, k);
            }
        }
        drain_wait(&c);
        let survivors = (0..64u64).filter(|&k| c.get(k).is_some()).count();
        assert!(survivors >= 32, "W-TinyLFU should protect hot keys, kept {survivors}/64");
    }

    #[test]
    fn concurrent_smoke_and_clean_shutdown() {
        let c = StdArc::new(CaffeineLike::new(1024));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(500 + t);
                for _ in 0..5_000 {
                    let key = rng.below(4096);
                    if rng.chance(0.3) {
                        c.put(key, key);
                    } else if let Some(v) = c.get(key) {
                        assert_eq!(v, key);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Drop joins the maintenance thread; must not hang.
    }
}
