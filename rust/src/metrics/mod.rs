//! Service metrics: lock-free counters and a log-bucketed latency
//! histogram (HdrHistogram-style, power-of-2 buckets with linear
//! sub-buckets) used by the coordinator's request path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-buckets per power-of-two bucket (higher = finer percentiles).
const SUB_BITS: u32 = 4;
const SUB: usize = 1 << SUB_BITS;
/// Covers 1ns .. ~2^40 ns (~18 minutes) of latency.
const BUCKETS: usize = 41;

/// A concurrent log-bucketed histogram of nanosecond latencies.
pub struct LatencyHistogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
    sum: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self {
            counts: (0..BUCKETS * SUB).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    fn index(nanos: u64) -> usize {
        let n = nanos.max(1);
        let bucket = (63 - n.leading_zeros()) as usize; // floor(log2 n)
        let sub = if bucket as u32 >= SUB_BITS {
            ((n >> (bucket as u32 - SUB_BITS)) as usize) & (SUB - 1)
        } else {
            (n as usize) & (SUB - 1)
        };
        (bucket.min(BUCKETS - 1)) * SUB + sub
    }

    /// Record one latency sample.
    #[inline]
    pub fn record(&self, nanos: u64) {
        self.counts[Self::index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Mean latency in nanoseconds.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Approximate percentile: the **lower bound** of the bucket holding
    /// the q-th sample. Every sample in a bucket is `>=` its lower bound,
    /// so the reported figure never exceeds the true percentile by more
    /// than rounding — the previous upper-bound convention overstated
    /// p50/p99 by up to one bucket width (~6% at 4 sub-bucket bits),
    /// which is exactly the margin resize-dip comparisons care about.
    pub fn percentile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for i in 0..self.counts.len() {
            seen += self.counts[i].load(Ordering::Relaxed);
            if seen >= target {
                let bucket = i / SUB;
                let sub = i % SUB;
                if (bucket as u32) < SUB_BITS {
                    // Sub-16ns values index by their own low bits: the
                    // sub-bucket *is* the exact recorded value.
                    return sub as u64;
                }
                let base = 1u64 << bucket;
                let width = 1u64 << (bucket as u32 - SUB_BITS);
                return base + sub as u64 * width;
            }
        }
        u64::MAX
    }

    /// Render a short summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}ns p50={} p99={} p99.9={}",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(99.0),
            self.percentile(99.9),
        )
    }
}

/// Named operation counters for the service.
#[derive(Default)]
pub struct OpCounters {
    /// Completed get operations.
    pub gets: AtomicU64,
    /// Completed put operations.
    pub puts: AtomicU64,
    /// Gets that found their key.
    pub hits: AtomicU64,
}

impl OpCounters {
    /// hits / gets (0 when nothing was read yet).
    pub fn hit_ratio(&self) -> f64 {
        let g = self.gets.load(Ordering::Relaxed);
        if g == 0 {
            0.0
        } else {
            self.hits.load(Ordering::Relaxed) as f64 / g as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_ordered_and_bracket_samples() {
        let h = LatencyHistogram::new();
        for n in 1..=10_000u64 {
            h.record(n);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        // p50 of uniform 1..10000 is ~5000; log buckets are coarse, allow 2x.
        assert!((2_500..=10_500).contains(&p50), "p50={p50}");
        assert!(p99 >= 9_000, "p99={p99}");
        assert!((h.mean() - 5000.5).abs() < 100.0);
    }

    #[test]
    fn percentile_reports_the_bucket_lower_bound() {
        // A point distribution pins the bound exactly: 1000 ns lands in
        // bucket 9 (width 32), whose containing sub-bucket spans
        // [992, 1024). Every percentile of a point mass at 1000 must
        // report 992 — *at most* the true value — where the old
        // upper-bound convention said 1024, overstating by a bucket
        // width.
        let h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(1000);
        }
        for q in [1.0, 50.0, 99.0, 99.9] {
            let p = h.percentile(q);
            assert_eq!(p, 992, "q={q}: expected the bucket lower bound");
            assert!(p <= 1000, "q={q}: a percentile must never exceed the sample");
        }
        // Small exact buckets (< 16 ns) report the exact value.
        let h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record(5);
        }
        assert_eq!(h.percentile(50.0), 5);
        // A two-point distribution keeps the quantile semantics: the
        // median of 900 ones and 100 large samples is the ones' bucket.
        let h = LatencyHistogram::new();
        for _ in 0..900 {
            h.record(1);
        }
        for _ in 0..100 {
            h.record(1_000_000);
        }
        assert_eq!(h.percentile(50.0), 1);
        assert!(h.percentile(95.0) >= 900_000, "p95 sits in the large bucket");
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for n in 0..10_000u64 {
                    h.record(n % 1000 + 1);
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn op_counters_ratio() {
        let c = OpCounters::default();
        c.gets.store(10, Ordering::Relaxed);
        c.hits.store(4, Ordering::Relaxed);
        assert!((c.hit_ratio() - 0.4).abs() < 1e-12);
    }
}
