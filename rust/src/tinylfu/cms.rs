//! TinyLFU frequency sketch: a 4-bit count-min sketch with a doorkeeper
//! Bloom filter and periodic halving ("reset" aging), following
//! Einziger, Friedman & Manes (ACM ToS 2017) — the admission substrate for
//! both the paper's "LFU + TinyLFU admission" configuration and the
//! Caffeine-like product baseline.

use crate::util::hash;

const ROWS: usize = 4;
const COUNTER_MAX: u64 = 15;

/// 4-bit count-min sketch + doorkeeper with periodic reset.
pub struct FrequencySketch {
    /// Each row is `width/16` u64 words, 16 nibble counters per word.
    rows: Vec<Vec<u64>>,
    width_mask: u64,
    /// Doorkeeper bloom filter bits.
    door: Vec<u64>,
    door_mask: u64,
    /// Accesses recorded since the last reset.
    additions: u64,
    /// Reset period (the TinyLFU "sample size", W = 10·C by default).
    sample_size: u64,
    resets: u64,
}

impl FrequencySketch {
    /// Sketch sized for a cache of `capacity` entries: counter width is
    /// the next power of two ≥ 8·capacity, sample size is 10·capacity.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(16);
        let width = (8 * capacity).next_power_of_two() as u64;
        let door_bits = (8 * capacity).next_power_of_two() as u64;
        Self {
            rows: (0..ROWS).map(|_| vec![0u64; (width / 16) as usize]).collect(),
            width_mask: width - 1,
            door: vec![0u64; (door_bits / 64) as usize],
            door_mask: door_bits - 1,
            additions: 0,
            sample_size: 10 * capacity as u64,
            resets: 0,
        }
    }

    #[inline]
    fn row_index(&self, key: u64, row: usize) -> (usize, u32) {
        let h = hash::xxh64_u64(key, 0x1234_5678 + row as u64);
        let slot = h & self.width_mask;
        ((slot / 16) as usize, ((slot % 16) * 4) as u32)
    }

    #[inline]
    fn door_bit(&self, key: u64, i: u64) -> (usize, u32) {
        let h = hash::xxh64_u64(key, 0xD00D + i);
        let bit = h & self.door_mask;
        ((bit / 64) as usize, (bit % 64) as u32)
    }

    fn door_contains(&self, key: u64) -> bool {
        (0..3).all(|i| {
            let (word, bit) = self.door_bit(key, i);
            self.door[word] >> bit & 1 == 1
        })
    }

    fn door_insert(&mut self, key: u64) {
        for i in 0..3 {
            let (word, bit) = self.door_bit(key, i);
            self.door[word] |= 1 << bit;
        }
    }

    /// Record one access. First-time keys only set the doorkeeper; repeat
    /// keys increment the sketch (saturating 4-bit counters). Every
    /// `sample_size` records, all counters are halved and the doorkeeper
    /// cleared — TinyLFU's aging mechanism.
    pub fn record(&mut self, key: u64) {
        if !self.door_contains(key) {
            self.door_insert(key);
        } else {
            for row in 0..ROWS {
                let (word, shift) = self.row_index(key, row);
                let counter = (self.rows[row][word] >> shift) & 0xF;
                if counter < COUNTER_MAX {
                    self.rows[row][word] += 1 << shift;
                }
            }
        }
        self.additions += 1;
        if self.additions >= self.sample_size {
            self.reset();
        }
    }

    /// Frequency estimate: sketch minimum plus the doorkeeper bit.
    pub fn estimate(&self, key: u64) -> u64 {
        let mut min = u64::MAX;
        for row in 0..ROWS {
            let (word, shift) = self.row_index(key, row);
            min = min.min((self.rows[row][word] >> shift) & 0xF);
        }
        min + u64::from(self.door_contains(key))
    }

    /// Halve every counter and clear the doorkeeper.
    fn reset(&mut self) {
        for row in &mut self.rows {
            for word in row.iter_mut() {
                // Halve each nibble: shift right then clear the bit that
                // leaked in from the neighbour nibble.
                *word = (*word >> 1) & 0x7777_7777_7777_7777;
            }
        }
        self.door.fill(0);
        self.additions = 0;
        self.resets += 1;
    }

    /// Number of resets so far (for tests and ablation reporting).
    pub fn resets(&self) -> u64 {
        self.resets
    }

    /// TinyLFU admission: admit `candidate` only if its estimated
    /// frequency exceeds the `victim`'s.
    pub fn admit(&self, candidate: u64, victim: u64) -> bool {
        self.estimate(candidate) > self.estimate(victim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimates_track_frequency() {
        let mut s = FrequencySketch::new(1024);
        for _ in 0..10 {
            s.record(42);
        }
        s.record(7);
        assert!(s.estimate(42) >= 8, "hot key underestimated: {}", s.estimate(42));
        assert!(s.estimate(7) <= 2);
        assert_eq!(s.estimate(999_999), 0);
    }

    #[test]
    fn doorkeeper_absorbs_singletons() {
        let mut s = FrequencySketch::new(1024);
        // One-hit wonders only set the doorkeeper; the sketch rows stay 0.
        for key in 0..100u64 {
            s.record(key);
        }
        for key in 0..100u64 {
            assert!(s.estimate(key) <= 1);
        }
    }

    #[test]
    fn counters_saturate() {
        let mut s = FrequencySketch::new(64);
        // sample_size = 640 for capacity 64; stay below it (500 records).
        for _ in 0..500 {
            s.record(1);
        }
        assert!(s.estimate(1) <= COUNTER_MAX + 1);
    }

    #[test]
    fn reset_halves() {
        let mut s = FrequencySketch::new(16);
        // capacity clamps to 16 -> sample = 160.
        for _ in 0..100 {
            s.record(5);
        }
        let before = s.estimate(5);
        for i in 0..100u64 {
            s.record(1000 + i); // push over the sample size
        }
        assert!(s.resets() >= 1);
        let after = s.estimate(5);
        assert!(after <= before / 2 + 1, "before={before} after={after}");
    }

    #[test]
    fn admit_prefers_frequent() {
        let mut s = FrequencySketch::new(1024);
        for _ in 0..8 {
            s.record(100);
        }
        s.record(200);
        assert!(s.admit(100, 200), "frequent candidate must be admitted");
        assert!(!s.admit(200, 100), "rare candidate must be rejected");
        assert!(!s.admit(300, 300), "equal frequency is not admitted");
    }
}
