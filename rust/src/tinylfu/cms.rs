//! TinyLFU frequency sketch: a 4-bit count-min sketch with a doorkeeper
//! Bloom filter and periodic halving ("reset" aging), following
//! Einziger, Friedman & Manes (ACM ToS 2017) — the admission substrate for
//! the paper's "LFU + TinyLFU admission" and "Hyperbolic + TinyLFU"
//! configurations, the Caffeine-like product baseline, and the concurrent
//! admission layer ([`super::TlfuCache`]).
//!
//! This is the crate's *single* sketch implementation, and it is
//! concurrent: `record`, `estimate` and `admit` all take `&self`.
//!
//! * **Counters** are 4-bit nibbles packed 16 to an `AtomicU64` word. An
//!   increment is one relaxed single-shot CAS of the whole word, which
//!   saturates the nibble and can never carry into a neighbour. Sketch
//!   increments are commutative, so threads never need to observe each
//!   other's updates in any particular order (cf. *Flexible Support for
//!   Fast Parallel Commutative Updates*, PAPERS.md); a CAS that loses its
//!   race is simply dropped, blurring the estimate by at most one access —
//!   the same "it is a cache" failure semantics the k-way caches use for
//!   policy touches.
//! * **Doorkeeper** bits are sharded over independent `AtomicU64` words
//!   updated with relaxed `fetch_or`; two threads racing the same fresh
//!   key both treat it as a first access, a one-count undercount.
//! * **Aging** is epoch-based: the record that crosses the sample boundary
//!   tries to claim the `aging` flag, and the single winner halves every
//!   counter word (whole-word load/store — readers can observe the old or
//!   the halved word, never a torn nibble) and clears the doorkeeper.
//!   Records that arrive mid-pass skip the claim and keep counting; the
//!   next post-pass crossing re-arms the epoch, so aging can never stall.
//!
//! Driven single-threaded (the hit-ratio simulator, [`super::TlfuSim`]),
//! every CAS succeeds and the flag is always free, so the sketch behaves
//! bit-for-bit like the sequential implementation it replaced — the sim
//! figures are unchanged.

use crate::util::hash;
use std::sync::atomic::{AtomicU64, Ordering};

const ROWS: usize = 4;
const COUNTER_MAX: u64 = 15;

/// Concurrent 4-bit count-min sketch + doorkeeper with periodic reset.
pub struct FrequencySketch {
    /// Each row is `width/16` words, 16 nibble counters per word.
    rows: Vec<Box<[AtomicU64]>>,
    width_mask: u64,
    /// Doorkeeper bloom-filter bits, sharded over independent words.
    door: Box<[AtomicU64]>,
    door_mask: u64,
    /// Accesses recorded since the last reset.
    additions: AtomicU64,
    /// Reset period (the TinyLFU "sample size", W = 10·C by default).
    /// Atomic so an online cache resize can re-derive it from the new
    /// capacity ([`FrequencySketch::rescale`]).
    sample_size: AtomicU64,
    /// Completed aging passes — the aging epoch.
    resets: AtomicU64,
    /// Aging mutual exclusion: non-zero while a halving pass runs.
    aging: AtomicU64,
}

impl FrequencySketch {
    /// Sketch sized for a cache of `capacity` entries: counter width is
    /// the next power of two ≥ 8·capacity, sample size is 10·capacity.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(16);
        let width = (8 * capacity).next_power_of_two() as u64;
        let door_bits = (8 * capacity).next_power_of_two() as u64;
        Self {
            rows: (0..ROWS)
                .map(|_| (0..width / 16).map(|_| AtomicU64::new(0)).collect())
                .collect(),
            width_mask: width - 1,
            door: (0..door_bits / 64).map(|_| AtomicU64::new(0)).collect(),
            door_mask: door_bits - 1,
            additions: AtomicU64::new(0),
            sample_size: AtomicU64::new(10 * capacity as u64),
            resets: AtomicU64::new(0),
            aging: AtomicU64::new(0),
        }
    }

    #[inline]
    fn row_index(&self, key: u64, row: usize) -> (usize, u32) {
        let h = hash::xxh64_u64(key, 0x1234_5678 + row as u64);
        let slot = h & self.width_mask;
        ((slot / 16) as usize, ((slot % 16) * 4) as u32)
    }

    #[inline]
    fn door_bit(&self, key: u64, i: u64) -> (usize, u32) {
        let h = hash::xxh64_u64(key, 0xD00D + i);
        let bit = h & self.door_mask;
        ((bit / 64) as usize, (bit % 64) as u32)
    }

    fn door_contains(&self, key: u64) -> bool {
        (0..3).all(|i| {
            let (word, bit) = self.door_bit(key, i);
            self.door[word].load(Ordering::Relaxed) >> bit & 1 == 1
        })
    }

    fn door_insert(&self, key: u64) {
        for i in 0..3 {
            let (word, bit) = self.door_bit(key, i);
            self.door[word].fetch_or(1 << bit, Ordering::Relaxed);
        }
    }

    /// Record one access. First-time keys only set the doorkeeper; repeat
    /// keys increment the sketch (saturating 4-bit counters). Every
    /// `sample_size` records, all counters are halved and the doorkeeper
    /// cleared — TinyLFU's aging mechanism. Safe to call from any number
    /// of threads; a lost increment race only blurs the estimate.
    pub fn record(&self, key: u64) {
        if !self.door_contains(key) {
            self.door_insert(key);
        } else {
            for row in 0..ROWS {
                let (word, shift) = self.row_index(key, row);
                let w = self.rows[row][word].load(Ordering::Relaxed);
                if (w >> shift) & 0xF < COUNTER_MAX {
                    // Single-shot CAS: a saturating nibble increment that
                    // can never carry into the neighbour nibble. Losing
                    // the race drops one commutative increment — benign.
                    // Strong CAS, not weak: it only fails on a real race,
                    // which keeps the single-threaded path deterministic
                    // on LL/SC targets too (the sim parity depends on it).
                    let _ = self.rows[row][word].compare_exchange(
                        w,
                        w + (1 << shift),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    );
                }
            }
        }
        if self.additions.fetch_add(1, Ordering::Relaxed) + 1
            >= self.sample_size.load(Ordering::Relaxed)
        {
            self.try_reset();
        }
    }

    /// Record a whole batch before any of it is probed — the batched
    /// access paths ([`super::TlfuCache`]'s `get_batch`) call this so the
    /// sketch updates for a chunk land together, mirroring the k-way
    /// prepare-then-probe batching discipline.
    pub fn record_batch(&self, keys: &[u64]) {
        for &key in keys {
            self.record(key);
        }
    }

    /// Frequency estimate: sketch minimum plus the doorkeeper bit.
    pub fn estimate(&self, key: u64) -> u64 {
        let mut min = u64::MAX;
        for row in 0..ROWS {
            let (word, shift) = self.row_index(key, row);
            min = min.min((self.rows[row][word].load(Ordering::Relaxed) >> shift) & 0xF);
        }
        min + u64::from(self.door_contains(key))
    }

    /// Run one aging pass if this thread wins the epoch flag. Every
    /// record past the boundary retries until one wins, so a pass that
    /// was skipped because another was in flight cannot stall the epoch.
    fn try_reset(&self) {
        if self
            .aging
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return; // another thread is aging right now
        }
        let sample_size = self.sample_size.load(Ordering::Relaxed);
        if self.additions.load(Ordering::Relaxed) >= sample_size {
            self.additions.fetch_sub(sample_size, Ordering::Relaxed);
            self.reset();
        }
        self.aging.store(0, Ordering::Release);
    }

    /// Re-derive the sample size from a resized cache capacity and run
    /// one immediate aging pass (halve every counter, clear the
    /// doorkeeper). Called on a *grow*: the frequencies the sketch
    /// accumulated were competitive against the old, smaller resident
    /// set, so aging them keeps admission from rejecting the fresh keys
    /// the grown cache now has room for. The counter *width* stays as
    /// sized at construction — estimates remain sound, just coarser
    /// relative to the larger sample (DESIGN.md §Elastic resizing).
    pub fn rescale(&self, capacity: usize) {
        self.sample_size.store(10 * capacity.max(16) as u64, Ordering::Relaxed);
        // Claim the aging flag like any other aging pass; spinning is
        // fine here (resizes are admin-rare, passes are short).
        while self.aging.compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed).is_err() {
            std::hint::spin_loop();
        }
        self.additions.store(0, Ordering::Relaxed);
        self.reset();
        self.aging.store(0, Ordering::Release);
    }

    /// Halve every counter and clear the doorkeeper. Runs on the single
    /// thread holding the aging flag; concurrent records may lose an
    /// increment against the halving stores — the documented
    /// relaxed-commutative trade.
    fn reset(&self) {
        for row in &self.rows {
            for word in row.iter() {
                // Halve each nibble: shift right then clear the bit that
                // leaked in from the neighbour nibble.
                let w = word.load(Ordering::Relaxed);
                word.store((w >> 1) & 0x7777_7777_7777_7777, Ordering::Relaxed);
            }
        }
        for word in self.door.iter() {
            word.store(0, Ordering::Relaxed);
        }
        self.resets.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of completed aging passes — the aging epoch (for tests,
    /// the concurrency smoke suite and ablation reporting).
    pub fn resets(&self) -> u64 {
        self.resets.load(Ordering::Relaxed)
    }

    /// TinyLFU admission: admit `candidate` only if its estimated
    /// frequency exceeds the `victim`'s.
    pub fn admit(&self, candidate: u64, victim: u64) -> bool {
        self.estimate(candidate) > self.estimate(victim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn estimates_track_frequency() {
        let s = FrequencySketch::new(1024);
        for _ in 0..10 {
            s.record(42);
        }
        s.record(7);
        assert!(s.estimate(42) >= 8, "hot key underestimated: {}", s.estimate(42));
        assert!(s.estimate(7) <= 2);
        assert_eq!(s.estimate(999_999), 0);
    }

    #[test]
    fn doorkeeper_absorbs_singletons() {
        let s = FrequencySketch::new(1024);
        // One-hit wonders only set the doorkeeper; the sketch rows stay 0.
        for key in 0..100u64 {
            s.record(key);
        }
        for key in 0..100u64 {
            assert!(s.estimate(key) <= 1);
        }
    }

    #[test]
    fn counters_saturate() {
        let s = FrequencySketch::new(64);
        // sample_size = 640 for capacity 64; stay below it (500 records).
        for _ in 0..500 {
            s.record(1);
        }
        assert!(s.estimate(1) <= COUNTER_MAX + 1);
    }

    #[test]
    fn rescale_ages_and_updates_sample_size() {
        let s = FrequencySketch::new(64);
        for _ in 0..12 {
            s.record(5);
        }
        let before = s.estimate(5);
        assert!(before >= 6, "hot key should be sketch-hot: {before}");
        let resets_before = s.resets();
        s.rescale(256); // grow: one immediate aging pass
        assert_eq!(s.resets(), resets_before + 1);
        let after = s.estimate(5);
        assert!(after < before, "aging must halve the estimate: {before} -> {after}");
        // The new sample size is in force: capacity 256 -> 2560 records
        // before the next natural aging pass.
        for i in 0..2_000u64 {
            s.record(10_000 + i);
        }
        assert_eq!(s.resets(), resets_before + 1, "below the grown sample size: no aging yet");
    }

    #[test]
    fn reset_halves() {
        let s = FrequencySketch::new(16);
        // capacity clamps to 16 -> sample = 160.
        for _ in 0..100 {
            s.record(5);
        }
        let before = s.estimate(5);
        for i in 0..100u64 {
            s.record(1000 + i); // push over the sample size
        }
        assert!(s.resets() >= 1);
        let after = s.estimate(5);
        assert!(after <= before / 2 + 1, "before={before} after={after}");
    }

    #[test]
    fn admit_prefers_frequent() {
        let s = FrequencySketch::new(1024);
        for _ in 0..8 {
            s.record(100);
        }
        s.record(200);
        assert!(s.admit(100, 200), "frequent candidate must be admitted");
        assert!(!s.admit(200, 100), "rare candidate must be rejected");
        assert!(!s.admit(300, 300), "equal frequency is not admitted");
    }

    #[test]
    fn record_batch_matches_scalar_records() {
        let batched = FrequencySketch::new(256);
        let scalar = FrequencySketch::new(256);
        let keys: Vec<u64> = (0..64u64).flat_map(|k| [k, k % 8]).collect();
        batched.record_batch(&keys);
        for &key in &keys {
            scalar.record(key);
        }
        for key in 0..64u64 {
            assert_eq!(batched.estimate(key), scalar.estimate(key), "key {key}");
        }
    }

    #[test]
    fn concurrent_records_accumulate() {
        // 4 threads × 1000 records of one hot key, all inside one sample
        // window (capacity 4096 -> sample 40960): the hot key must end up
        // saturated even though increments race.
        let s = Arc::new(FrequencySketch::new(4096));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    s.record(7);
                    s.record(1_000_000 + t * 10_000 + i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(s.estimate(7) >= COUNTER_MAX, "hot key estimate {}", s.estimate(7));
    }

    #[test]
    fn concurrent_aging_advances_epoch_without_stalling() {
        // Tiny sketch (sample 160) hammered by 4 threads: the epoch must
        // advance many times and never deadlock or panic.
        let s = Arc::new(FrequencySketch::new(16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    s.record(t * 100_000 + i % 512);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // 40_000 records / sample 160 ≈ 250 crossings; allow generous
        // slippage for crossings that coalesce under contention.
        assert!(s.resets() >= 10, "aging epoch stalled at {}", s.resets());
    }
}
