//! TinyLFU admission as a first-class concurrent cache layer.
//!
//! The paper's headline throughput configurations pair an eviction policy
//! with TinyLFU admission ("LFU + TinyLFU", "Hyperbolic + TinyLFU" —
//! Figures 4–13, subfigures b/d). Before this layer existed the repo could
//! only simulate those single-threaded ([`super::TlfuSim`]); [`TlfuCache`]
//! composes the same admission filter with *any* concurrent
//! [`Cache`], including the batched access path, so the multi-threaded
//! throughput harness, the coordinator service and the benches can all
//! run the admission configurations the paper promotes.
//!
//! The composition point is [`Cache::peek_victim`]: the inner cache
//! previews which key an insert would evict, and the sketch admits the
//! candidate only when its estimated frequency beats that victim's. Under
//! concurrency the preview is *advisory* — by the time the put lands the
//! set may have chosen a different victim — but admission is a
//! probabilistic filter to begin with, so a stale preview only blurs the
//! decision by one access, never safety (DESIGN.md §Admission).
//!
//! Recording policy: every `get` records its key (hit or miss, exactly
//! like the simulator's read-then-fill methodology), and every `put`
//! records its candidate before the admission check (like Caffeine's
//! write-path recording) so caches that are seeded through bare puts can
//! still build frequency. The batched paths record the whole chunk into
//! the sketch before the first probe — the same prepare-then-probe
//! discipline the k-way batched paths use for hashing and prefetching.

use super::FrequencySketch;
use crate::lifetime::{BatchEntry, EntryOpts};
use crate::Cache;
use std::sync::Arc;

/// An admission filter: decides whether a candidate may displace a
/// victim, fed by a stream of recorded accesses. Object-safe and
/// `&self`-based so implementations can sit in front of any concurrent
/// cache. [`FrequencySketch`] is the one implementation today; the trait
/// is the seam for alternative filters (ghost caches, per-tenant
/// sketches) without touching the wrapper or the wiring.
pub trait Admission: Send + Sync {
    /// Record one access to `key`.
    fn record(&self, key: u64);
    /// Record a whole batch before it is probed (batched access paths).
    fn record_batch(&self, keys: &[u64]) {
        for &key in keys {
            self.record(key);
        }
    }
    /// Should `candidate` displace `victim`?
    fn admit(&self, candidate: u64, victim: u64) -> bool;
}

impl Admission for FrequencySketch {
    fn record(&self, key: u64) {
        FrequencySketch::record(self, key);
    }
    fn admit(&self, candidate: u64, victim: u64) -> bool {
        FrequencySketch::admit(self, candidate, victim)
    }
}

/// Which admission filter to layer over a cache — the CLI/config surface
/// (`--admission none|tlfu`) shared by the throughput harness, the
/// coordinator service and the benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// No admission: every put goes straight to the cache.
    None,
    /// TinyLFU admission through a [`TlfuCache`] wrapper.
    TinyLfu,
}

impl AdmissionMode {
    /// Both modes, for sweeps.
    pub const ALL: [AdmissionMode; 2] = [AdmissionMode::None, AdmissionMode::TinyLfu];

    /// Parse from a CLI string (`none`/`off`, `tlfu`/`tinylfu`).
    pub fn parse(s: &str) -> Option<AdmissionMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(AdmissionMode::None),
            "tlfu" | "tinylfu" => Some(AdmissionMode::TinyLfu),
            _ => None,
        }
    }

    /// Canonical CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionMode::None => "none",
            AdmissionMode::TinyLfu => "tlfu",
        }
    }

    /// Suffix for implementation labels in tables and reports.
    pub fn label(&self) -> &'static str {
        match self {
            AdmissionMode::None => "",
            AdmissionMode::TinyLfu => "+TLFU",
        }
    }

    /// Layer this admission mode over an already-shared cache. The sketch
    /// is sized from the cache's own capacity.
    pub fn wrap(&self, cache: Arc<dyn Cache>) -> Arc<dyn Cache> {
        match self {
            AdmissionMode::None => cache,
            AdmissionMode::TinyLfu => {
                let capacity = cache.capacity();
                Arc::new(TlfuCache::new(cache, capacity))
            }
        }
    }
}

/// TinyLFU admission wrapped around any concurrent cache. Implements the
/// full [`Cache`] trait — including the batched paths and the lifetime
/// dimension — so it drops into every layer that takes a cache: the
/// throughput harness, the coordinator service, the benches and the CLI.
///
/// ```
/// use kway::kway::KwWfsc;
/// use kway::policy::Policy;
/// use kway::tinylfu::TlfuCache;
/// use kway::Cache;
///
/// let cache = TlfuCache::new(KwWfsc::new(1 << 10, 8, Policy::Lru), 1 << 10);
/// assert_eq!(cache.name(), "KW-WFSC+TLFU");
/// assert!(cache.put_admitted(7, 70), "free room always admits");
/// assert_eq!(cache.get(7), Some(70));
/// assert!(cache.supports_lifetime(), "lifetime support is forwarded");
/// ```
pub struct TlfuCache<C: Cache> {
    inner: C,
    sketch: FrequencySketch,
    /// `"{inner}+TLFU"`, leaked once per cache so [`Cache::name`] can stay
    /// `&'static str` (a few bytes per constructed cache, not per op).
    name: &'static str,
}

impl<C: Cache> TlfuCache<C> {
    /// Wrap `inner` with a TinyLFU filter whose sketch is sized for
    /// `capacity` entries.
    pub fn new(inner: C, capacity: usize) -> Self {
        let name = Box::leak(format!("{}+TLFU", inner.name()).into_boxed_str());
        Self { inner, sketch: FrequencySketch::new(capacity), name }
    }

    /// The wrapped cache.
    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// The shared frequency sketch (tests read the aging epoch here).
    pub fn sketch(&self) -> &FrequencySketch {
        &self.sketch
    }

    /// Admission verdict for one candidate whose access is already
    /// recorded. `peek_victim` cannot tell whether the candidate is
    /// already resident, so a rejected candidate gets one residency probe:
    /// an update of a resident key must never be dropped (it would leave a
    /// stale value readable).
    fn admits(&self, key: u64) -> bool {
        match self.inner.peek_victim(key) {
            // Free room (or no preview support): always admit.
            None => true,
            // The probed key is itself the policy victim — an overwrite.
            Some(victim) if victim == key => true,
            Some(victim) => {
                self.sketch.admit(key, victim) || self.inner.get(key).is_some()
            }
        }
    }

    /// `put` that reports whether the candidate was admitted (the
    /// concurrency smoke suite asserts on this).
    pub fn put_admitted(&self, key: u64, value: u64) -> bool {
        self.sketch.record(key);
        if self.admits(key) {
            self.inner.put(key, value);
            true
        } else {
            false
        }
    }

    /// [`TlfuCache::put_admitted`] with lifetime/weight options: the
    /// admission decision is identical (the sketch scores *keys*, not
    /// lifetimes), the options are simply forwarded to the inner cache.
    pub fn put_with_admitted(&self, key: u64, value: u64, opts: EntryOpts) -> bool {
        self.sketch.record(key);
        if self.admits(key) {
            self.inner.put_with(key, value, opts);
            true
        } else {
            false
        }
    }
}

impl<C: Cache> Cache for TlfuCache<C> {
    fn get(&self, key: u64) -> Option<u64> {
        // TinyLFU records every access, hit or miss.
        self.sketch.record(key);
        self.inner.get(key)
    }

    fn put(&self, key: u64, value: u64) {
        self.put_admitted(key, value);
    }

    fn put_with(&self, key: u64, value: u64, opts: EntryOpts) {
        self.put_with_admitted(key, value, opts);
    }

    fn get_batch(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        // Record the whole chunk before the first probe, then let the
        // inner cache run its own batched (prefetching) path.
        self.sketch.record_batch(keys);
        self.inner.get_batch(keys, out);
    }

    fn put_batch(&self, items: &[(u64, u64)]) {
        for &(key, _) in items {
            self.sketch.record(key);
        }
        let mut admitted: Vec<(u64, u64)> = Vec::with_capacity(items.len());
        for &(key, value) in items {
            if self.admits(key) {
                admitted.push((key, value));
            }
        }
        if !admitted.is_empty() {
            self.inner.put_batch(&admitted);
        }
    }

    fn put_batch_with(&self, items: &[BatchEntry]) {
        // Same discipline as `put_batch`: record the whole chunk before
        // the first probe, filter by admission, forward the survivors
        // through the inner cache's batched lifetime path.
        for item in items {
            self.sketch.record(item.key);
        }
        let mut admitted: Vec<BatchEntry> = Vec::with_capacity(items.len());
        for item in items {
            if self.admits(item.key) {
                admitted.push(*item);
            }
        }
        if !admitted.is_empty() {
            self.inner.put_batch_with(&admitted);
        }
    }

    fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    fn requested_capacity(&self) -> usize {
        self.inner.requested_capacity()
    }

    fn supports_resize(&self) -> bool {
        self.inner.supports_resize()
    }

    fn resize(&self, new_capacity: usize) -> bool {
        // Forward to the inner cache; on a successful *grow*, re-age the
        // sketch: its frequencies were competitive against the old,
        // smaller resident set, and stale high counts would keep
        // rejecting the fresh keys the grown cache now has room for. A
        // shrink keeps the sketch as-is — the survivors' frequencies are
        // exactly the signal the tighter admission fight needs. Compared
        // against the *requested* capacity: while a previous resize is
        // still migrating, `capacity()` reports the larger live geometry,
        // which would mis-classify a real grow as a shrink.
        let grew = new_capacity > self.inner.requested_capacity();
        let accepted = self.inner.resize(new_capacity);
        if accepted && grew {
            self.sketch.rescale(new_capacity);
        }
        accepted
    }

    fn resize_step(&self, max_sets: usize) -> usize {
        self.inner.resize_step(max_sets)
    }

    fn resize_pending(&self) -> bool {
        self.inner.resize_pending()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn weight(&self) -> u64 {
        self.inner.weight()
    }

    fn supports_lifetime(&self) -> bool {
        self.inner.supports_lifetime()
    }

    fn sweep_expired(&self, max_sets: usize) -> usize {
        self.inner.sweep_expired(max_sets)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn peek_victim(&self, key: u64) -> Option<u64> {
        self.inner.peek_victim(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;

    /// Drive the read-then-fill loop the evaluation uses.
    fn access(cache: &TlfuCache<KwWfsc>, key: u64) -> bool {
        if cache.get(key).is_some() {
            true
        } else {
            cache.put(key, key.wrapping_mul(31));
            false
        }
    }

    #[test]
    fn name_and_forwarding() {
        let c = TlfuCache::new(KwWfsc::new(256, 8, Policy::Lru), 256);
        assert_eq!(c.name(), "KW-WFSC+TLFU");
        assert_eq!(c.capacity(), 256);
        assert!(c.is_empty());
        c.put(1, 10);
        assert_eq!(c.get(1), Some(10));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn admits_into_free_room() {
        let c = TlfuCache::new(KwWfsc::new(1024, 8, Policy::Lru), 1024);
        assert!(c.put_admitted(5, 50), "free room must always admit");
        assert_eq!(c.get(5), Some(50));
    }

    #[test]
    fn protects_hot_set_from_scan() {
        // One set (capacity 8, 8 ways) under LFU: make 8 keys hot, then
        // scan 200 cold keys through. Admission must keep the hot set.
        let c = TlfuCache::new(KwWfsc::new(8, 8, Policy::Lfu), 8);
        for _ in 0..20 {
            for key in 0..8u64 {
                access(&c, key);
            }
        }
        for key in 1000..1200u64 {
            access(&c, key);
        }
        let survivors = (0..8u64).filter(|&k| c.inner().get(k).is_some()).count();
        assert!(survivors >= 6, "hot set lost to scan: {survivors}/8 kept");
    }

    #[test]
    fn resident_key_update_is_never_dropped() {
        // Fill the single set, then overwrite a resident key while the
        // set is full and admission would *reject* it as a fresh insert:
        // the update must land anyway (a stale value readable after a
        // dropped update is a correctness bug, not a policy choice).
        // FIFO pins the victim to key 0 (oldest insert) no matter how hot
        // it gets, so making 0 sketch-hot forces the rejection path.
        let c = TlfuCache::new(KwWfsc::new(4, 4, Policy::Fifo), 4);
        for key in 0..4u64 {
            c.put(key, key);
        }
        for _ in 0..30 {
            let _ = c.get(0);
        }
        c.put(2, 999);
        assert_eq!(c.inner().get(2), Some(999), "resident update was dropped");
    }

    #[test]
    fn batched_get_records_and_matches_scalar() {
        let c = TlfuCache::new(KwWfsc::new(4096, 8, Policy::Lru), 4096);
        for key in 0..300u64 {
            c.put(key, key + 7);
        }
        let keys: Vec<u64> = (0..600u64).collect();
        let mut out = Vec::new();
        c.get_batch(&keys, &mut out);
        assert_eq!(out.len(), keys.len());
        for (i, &key) in keys.iter().enumerate() {
            let expect = if key < 300 { Some(key + 7) } else { None };
            assert_eq!(out[i], expect, "position {i}");
        }
        // The batch was recorded: repeated keys have built frequency.
        assert!(c.sketch().estimate(0) >= 1);
    }

    #[test]
    fn batched_put_admits_into_free_room() {
        let c = TlfuCache::new(KwWfsc::new(4096, 8, Policy::Lru), 4096);
        let items: Vec<(u64, u64)> = (0..300u64).map(|k| (k, k * 3)).collect();
        c.put_batch(&items);
        for &(k, v) in &items {
            assert_eq!(c.get(k), Some(v), "key {k}");
        }
    }

    #[test]
    fn expired_victims_always_admit() {
        use std::time::Duration;
        // One full set whose lines are all expired: even a sketch-cold
        // candidate must be admitted, because `peek_victim` reports an
        // expired line as free room (no live entry is displaced).
        let c = TlfuCache::new(KwWfsc::new(4, 4, Policy::Lfu), 4);
        for key in 0..4u64 {
            c.put_with(key, key, crate::lifetime::EntryOpts::ttl(Duration::ZERO));
        }
        assert!(c.put_with_admitted(100, 100, crate::lifetime::EntryOpts::default()));
        assert_eq!(c.get(100), Some(100));
    }

    #[test]
    fn put_with_forwards_lifetime_through_admission() {
        use std::time::Duration;
        let c = TlfuCache::new(KwWfsc::new(1024, 8, Policy::Lru), 1024);
        c.put_with(5, 50, crate::lifetime::EntryOpts::ttl(Duration::ZERO));
        assert_eq!(c.get(5), None, "expired keys are never returned through the wrapper");
        c.put_with(6, 60, crate::lifetime::EntryOpts::ttl(Duration::from_secs(3600)));
        assert_eq!(c.get(6), Some(60));
        // Batched variant: per-item opts survive the admission filter.
        let items: Vec<crate::lifetime::BatchEntry> = (10..20u64)
            .map(|k| {
                let opts = if k % 2 == 0 {
                    crate::lifetime::EntryOpts::ttl(Duration::ZERO)
                } else {
                    crate::lifetime::EntryOpts::default()
                };
                crate::lifetime::BatchEntry::new(k, k + 1, opts)
            })
            .collect();
        c.put_batch_with(&items);
        for k in 10..20u64 {
            let expect = if k % 2 == 0 { None } else { Some(k + 1) };
            assert_eq!(c.get(k), expect, "key {k}");
        }
    }

    #[test]
    fn resize_forwards_and_reages_the_sketch_on_grow() {
        let c = TlfuCache::new(KwWfsc::new(256, 8, Policy::Lru), 256);
        assert!(c.supports_resize(), "k-way support must forward through the wrapper");
        for _ in 0..10 {
            let _ = c.get(42); // build sketch frequency
        }
        let hot_before = c.sketch().estimate(42);
        assert!(hot_before >= 5);
        let resets_before = c.sketch().resets();
        assert!(c.resize(512));
        while c.resize_pending() {
            c.resize_step(16);
        }
        assert_eq!(c.capacity(), 512);
        assert_eq!(c.requested_capacity(), 512);
        assert_eq!(c.sketch().resets(), resets_before + 1, "grow must re-age the sketch");
        assert!(c.sketch().estimate(42) < hot_before);
        // A shrink forwards but does not re-age.
        let resets = c.sketch().resets();
        assert!(c.resize(256));
        while c.resize_pending() {
            c.resize_step(16);
        }
        assert_eq!(c.sketch().resets(), resets, "shrink keeps the sketch as-is");
    }

    #[test]
    fn admission_mode_parse_and_wrap() {
        assert_eq!(AdmissionMode::parse("tlfu"), Some(AdmissionMode::TinyLfu));
        assert_eq!(AdmissionMode::parse("TinyLFU"), Some(AdmissionMode::TinyLfu));
        assert_eq!(AdmissionMode::parse("none"), Some(AdmissionMode::None));
        assert_eq!(AdmissionMode::parse("bogus"), None);
        let base: Arc<dyn Cache> = Arc::new(KwWfsc::new(256, 8, Policy::Lru));
        let plain = AdmissionMode::None.wrap(base.clone());
        assert_eq!(plain.name(), "KW-WFSC");
        let wrapped = AdmissionMode::TinyLfu.wrap(base);
        assert_eq!(wrapped.name(), "KW-WFSC+TLFU");
        wrapped.put(9, 90);
        assert_eq!(wrapped.get(9), Some(90));
    }
}
