//! TinyLFU admission filtering (Einziger, Friedman & Manes, ACM ToS 2017).
//!
//! The paper evaluates "LFU eviction with TinyLFU admission" and
//! "Hyperbolic + TinyLFU" configurations (Figures 4–13, subfigures b/d):
//! the eviction policy proposes a victim, and the TinyLFU sketch admits the
//! candidate only when its estimated frequency exceeds the victim's. The
//! k-way caches preview their victim per-set, which is precisely the
//! "limited associativity TinyLFU" the paper promotes.
//!
//! There is exactly **one** frequency-sketch implementation
//! ([`FrequencySketch`], concurrent — see `cms.rs`), shared by two
//! composition layers:
//!
//! * [`TlfuSim`] — the sequential wrapper the hit-ratio simulator uses
//!   (records on `sim_get`, admits on `sim_put`, single-threaded).
//! * [`TlfuCache`] — the concurrent first-class layer: wraps any
//!   [`crate::Cache`] (including the batched paths) so the throughput
//!   harness, the coordinator service and the CLI can run admission
//!   configurations multi-threaded. Selected via [`AdmissionMode`]
//!   (`--admission tlfu`).

pub mod cms;
pub mod concurrent;

pub use cms::FrequencySketch;
pub use concurrent::{Admission, AdmissionMode, TlfuCache};

use crate::fully::SimVictimPeek;
use crate::SimCache;

/// TinyLFU admission wrapped around a simulated cache.
pub struct TlfuSim<C> {
    inner: C,
    sketch: FrequencySketch,
}

impl<C: SimCache + SimVictimPeek> TlfuSim<C> {
    /// Wrap `inner` with a TinyLFU filter sized for `capacity` entries.
    pub fn new(inner: C, capacity: usize) -> Self {
        Self { inner, sketch: FrequencySketch::new(capacity) }
    }

    /// The wrapped cache.
    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// The frequency sketch (tests read the aging epoch here).
    pub fn sketch(&self) -> &FrequencySketch {
        &self.sketch
    }
}

impl<C: SimCache + SimVictimPeek> SimCache for TlfuSim<C> {
    fn sim_get(&mut self, key: u64) -> bool {
        // TinyLFU records every access, hit or miss.
        self.sketch.record(key);
        self.inner.sim_get(key)
    }

    fn sim_put(&mut self, key: u64) {
        // The access was already recorded by the preceding get (the
        // simulator's read-then-put-on-miss methodology); admission
        // compares the candidate against the victim its set would evict.
        match self.inner.sim_peek_victim(key) {
            None => self.inner.sim_put(key), // free room: always admit
            Some(victim) => {
                if self.sketch.admit(key, victim) {
                    self.inner.sim_put(key);
                }
            }
        }
    }

    fn sim_name(&self) -> String {
        format!("{}+TLFU", self.inner.sim_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fully::LruList;
    use crate::kway::KwWfsc;
    use crate::policy::Policy;

    /// Drive the read-then-put-on-miss loop the evaluation uses.
    fn access<C: SimCache>(cache: &mut C, key: u64) -> bool {
        let hit = cache.sim_get(key);
        if !hit {
            cache.sim_put(key);
        }
        hit
    }

    #[test]
    fn protects_frequent_items_from_scans() {
        // Fill a small LRU with hot keys, make them frequent, then blast a
        // one-pass scan: without TinyLFU the scan evicts everything; with
        // it, the hot keys survive.
        let mut plain = LruList::new(8);
        let mut tlfu = TlfuSim::new(LruList::new(8), 8);
        for _ in 0..20 {
            for key in 0..8u64 {
                access(&mut plain, key);
                access(&mut tlfu, key);
            }
        }
        for key in 1000..1100u64 {
            access(&mut plain, key);
            access(&mut tlfu, key);
        }
        let plain_hot = (0..8u64).filter(|&k| plain.sim_get(k)).count();
        let mut tlfu_hot = 0;
        for k in 0..8u64 {
            if tlfu.sim_get(k) {
                tlfu_hot += 1;
            }
        }
        assert_eq!(plain_hot, 0, "plain LRU should have lost the hot set to the scan");
        assert!(tlfu_hot >= 6, "TinyLFU should protect the hot set, kept {tlfu_hot}/8");
    }

    #[test]
    fn composes_with_kway() {
        let mut c = TlfuSim::new(KwWfsc::new(64, 8, Policy::Lfu), 64);
        for round in 0..10 {
            for key in 0..32u64 {
                let hit = access(&mut c, key);
                if round > 2 {
                    assert!(hit, "stable working set must hit (round {round}, key {key})");
                }
            }
        }
        assert!(c.sim_name().contains("KW-WFSC"));
        assert!(c.sim_name().contains("TLFU"));
    }

    #[test]
    fn admits_into_free_room() {
        let mut c = TlfuSim::new(LruList::new(4), 4);
        assert!(!access(&mut c, 1));
        assert!(c.sim_get(1), "first insert must be admitted while cache has room");
    }
}
