//! Reproduce a hit-ratio figure (Figures 4–13 style): all four subfigure
//! series on one trace, across cache sizes.
//!
//! ```bash
//! cargo run --release --example hitratio_sweep -- oltp
//! ```

use kway::sim;
use kway::trace::paper;

fn main() {
    let trace_name = std::env::args().nth(1).unwrap_or_else(|| "oltp".into());
    let len = 400_000;
    let trace = paper::build(&trace_name, len, 42)
        .unwrap_or_else(|| panic!("unknown trace model {trace_name:?} (see `kway info`)"));
    println!(
        "trace={} accesses={} unique={}",
        trace.name,
        trace.len(),
        trace.unique_keys()
    );

    let sizes = [512usize, 2048, 8192];
    let series: [(&str, Vec<sim::Config>); 4] = [
        ("(a) LRU", sim::lru_series()),
        ("(b) LFU + TinyLFU admission", sim::lfu_tlfu_series()),
        ("(c) products", sim::products_series(8)),
        ("(d) Hyperbolic", sim::hyperbolic_series(false)),
    ];

    for (title, configs) in series {
        println!("\n== {title} ==");
        print!("{:34}", "config\\cache size");
        for s in sizes {
            print!(" {s:>8}");
        }
        println!();
        let per_size: Vec<Vec<sim::Row>> =
            sizes.iter().map(|&s| sim::sweep(&trace, s, &configs, 1)).collect();
        for (i, cfg) in configs.iter().enumerate() {
            print!("{:34}", cfg.label());
            for rows in &per_size {
                print!(" {:8.4}", rows[i].hit_ratio);
            }
            println!();
        }
    }
}
