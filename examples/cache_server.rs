//! End-to-end driver: every layer of the system composing on a real
//! workload.
//!
//! 1. **Layer 3 serving path** — a [`kway::coordinator::CacheService`]
//!    (router + worker pool) over the wait-free KW-WFSC cache serves
//!    batched get/put requests from concurrent clients replaying the
//!    `wiki_a` trace model; we report throughput, latency percentiles and
//!    the measured hit ratio. The service runs with a **default TTL**
//!    (`ServiceConfig::default_ttl`), so every fill is mortal and the
//!    run exercises lazy per-set expiration under real traffic, plus the
//!    incremental sweep hook between phases.
//! 2. **Layers 1–2 analytics path** — the AOT-compiled XLA artifact
//!    (Pallas set-scan kernels inside a lax.scan cache simulator) replays
//!    the *same* trace through PJRT and predicts the hit ratio; we check
//!    the prediction against both the native set simulator and the live
//!    service measurement. With the vendored PJRT stub (no `make
//!    artifacts`) this phase reports itself unavailable and the example
//!    still completes as a layer-3 smoke test.
//!
//! ```bash
//! cargo run --release --example cache_server            # layer 3 only
//! make artifacts && cargo run --release --example cache_server  # + XLA
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use kway::coordinator::{CacheService, ServiceConfig};
use kway::kway::KwWfsc;
use kway::policy::Policy;
use kway::runtime::XlaRuntime;
use kway::sim::xla::{NativeSetSim, XlaSim};
use kway::trace::paper;
use kway::{Cache, EntryOpts};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("KWAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let clients = 4usize;
    let batch = 32usize;

    // ---- Layers 1–2 (optional): load the AOT artifacts and bind the
    // simulator. With the vendored xla stub this fails cleanly and the
    // example degrades to the layer-3 serving smoke test.
    let runtime = match XlaRuntime::load(&artifacts) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("XLA layers unavailable ({e:#}); running layer 3 only");
            None
        }
    };
    let xla = match &runtime {
        Some(rt) => {
            let sim = XlaSim::new(rt, "cache_sim_k8")?;
            println!(
                "loaded {} artifacts on {} (cache_sim: {} sets x {} ways)",
                rt.entry_names().len(),
                rt.platform(),
                sim.num_sets,
                sim.ways
            );
            Some(sim)
        }
        None => None,
    };
    let (capacity, ways) = match &xla {
        Some(sim) => (sim.capacity(), sim.ways),
        None => (1 << 11, 8), // the paper's small-cache setup
    };

    // The workload: the Wikipedia trace model.
    let trace = Arc::new(paper::build("wiki_a", 400_000, 42).unwrap());
    println!("trace={} accesses={} unique={}", trace.name, trace.len(), trace.unique_keys());

    // ---- Offline prediction through PJRT (python is NOT involved).
    let predicted = match &xla {
        Some(sim) => {
            let t0 = Instant::now();
            let predicted = sim.run(trace.as_ref())?;
            let xla_secs = t0.elapsed().as_secs_f64();
            let native = NativeSetSim::new(sim.num_sets, sim.ways).run(&trace.keys);
            println!(
                "XLA cache_sim: {} hits / {} accesses = {:.4} ({:.2} Mkeys/s); native agrees: {}",
                predicted.hits,
                predicted.accesses,
                predicted.hits as f64 / predicted.accesses as f64,
                predicted.accesses as f64 / xla_secs / 1e6,
                predicted.hits == native.hits
            );
            assert_eq!(predicted.hits, native.hits, "layer 1/2 vs layer 3 divergence");
            Some(predicted)
        }
        None => None,
    };

    // ---- Layer 3: serve the same trace through the cache service. A
    // default TTL far beyond the replay duration means nothing expires
    // mid-run (the hit ratio stays comparable to the immortal
    // configuration and to the XLA prediction) while every entry still
    // takes the mortal code path end to end.
    let default_ttl = Duration::from_secs(300);
    let cache: Arc<dyn Cache> = Arc::new(KwWfsc::new(capacity, ways, Policy::Lru));
    let service = Arc::new(CacheService::start(
        cache,
        ServiceConfig { workers: 2, default_ttl: Some(default_ttl), ..Default::default() },
    ));
    let next = Arc::new(AtomicUsize::new(0));

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            let service = service.clone();
            let trace = trace.clone();
            let next = next.clone();
            scope.spawn(move || loop {
                let start = next.fetch_add(batch, Ordering::Relaxed);
                if start >= trace.len() {
                    return;
                }
                let end = (start + batch).min(trace.len());
                let keys: Vec<u64> = trace.keys[start..end].to_vec();
                let results = service.get_batch(keys.clone());
                for (key, value) in keys.into_iter().zip(results) {
                    if value.is_none() {
                        service.put(key, key); // carries the default TTL
                    }
                }
            });
        }
    });
    let serve_secs = t0.elapsed().as_secs_f64();

    let m = service.metrics();
    let measured_ratio = m.ops.hit_ratio();
    println!(
        "\nservice: {} requests in {:.2}s = {:.2} Mops/s (default ttl {default_ttl:?})",
        trace.len(),
        serve_secs,
        trace.len() as f64 / serve_secs / 1e6
    );
    println!("{}", m.report());

    // ---- TTL smoke test: the service's entries are mortal. An explicit
    // zero-TTL put is never readable, and one incremental sweep pass
    // reclaims it in place — no background expiry thread exists anywhere
    // in the system (DESIGN.md §Expiration).
    service.put_with(u64::MAX - 3, 1, EntryOpts::ttl(Duration::ZERO));
    assert_eq!(service.get(u64::MAX - 3), None, "an expired key must never be served");
    let before = service.cache().len();
    let reclaimed = service.cache().sweep_expired(usize::MAX);
    println!(
        "ttl: {before} resident entries ({default_ttl:?} default TTL), one sweep pass \
         reclaimed {reclaimed} already-dead line(s); {} remain mortal",
        service.cache().len()
    );
    assert!(reclaimed >= 1, "the zero-TTL key must be reclaimed by the sweep");
    assert!(service.cache().len() < before);

    // ---- Cross-check: the XLA prediction must match the service's
    // measured hit ratio (same geometry, same LRU semantics; the service
    // replays the identical access sequence, modulo client interleaving
    // which perturbs LRU order only slightly).
    if let Some(predicted) = predicted {
        let predicted_ratio = predicted.hits as f64 / predicted.accesses as f64;
        println!(
            "\npredicted (XLA) hit ratio = {predicted_ratio:.4}, measured (service) = \
             {measured_ratio:.4}"
        );
        let gap = (predicted_ratio - measured_ratio).abs();
        assert!(gap < 0.03, "offline prediction and live measurement diverged by {gap:.4}");
        println!("end-to-end OK: all three layers agree.");
    } else {
        println!("\nend-to-end OK (layer 3 + TTL path; rerun with artifacts for the XLA check).");
    }
    Arc::try_unwrap(service).ok().map(|s| s.shutdown());
    Ok(())
}
