//! The Layers 1–2 pipeline from the rust side: load every AOT artifact,
//! exercise each kernel family with real inputs, and time them.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_pipeline
//! ```

use kway::runtime::{lit_i32, to_vec, XlaRuntime};
use kway::sim::xla::XlaSim;
use kway::trace::paper;
use kway::util::rng::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("KWAY_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = XlaRuntime::load(&dir)?;
    println!("platform={} producer={}", rt.platform(), rt.manifest().producer);

    // --- victim_select: batched eviction decisions (Pallas argmin).
    for name in ["victim_select_lru_k4", "victim_select_lru_k8", "victim_select_lru_k16"] {
        let spec = rt.manifest().entry(name).unwrap();
        let (b, k) = (spec.require("batch")? as usize, spec.require("k")? as usize);
        let mut rng = Rng::new(1);
        let counters: Vec<i32> = (0..b * k).map(|_| rng.below(1 << 30) as i32).collect();
        let arg = lit_i32(&counters, &[b as i64, k as i64])?;
        let t = Instant::now();
        let iters = 20;
        let mut out = Vec::new();
        for _ in 0..iters {
            out = rt.execute(name, std::slice::from_ref(&arg))?;
        }
        let per = t.elapsed().as_secs_f64() / iters as f64;
        let victims = to_vec::<i32>(&out[0])?;
        println!(
            "{name}: {b} sets/batch, {:.1} Msets/s (first victims: {:?})",
            b as f64 / per / 1e6,
            &victims[..4]
        );
    }

    // --- sketch estimate + update round trip.
    let spec = rt.manifest().entry("sketch_estimate").unwrap();
    let (d, w, b) = (
        spec.require("depth")? as usize,
        spec.require("width")? as usize,
        spec.require("batch")? as usize,
    );
    let mut rng = Rng::new(2);
    let rows = vec![0i32; d * w];
    let idx: Vec<i32> = (0..b * d).map(|_| rng.below(w as u64) as i32).collect();
    let rows_lit = lit_i32(&rows, &[d as i64, w as i64])?;
    let idx_lit = lit_i32(&idx, &[b as i64, d as i64])?;
    let updated = rt.execute("sketch_update", &[rows_lit, idx_lit])?;
    let est = rt.execute(
        "sketch_estimate",
        &[updated.into_iter().next().unwrap(), lit_i32(&idx, &[b as i64, d as i64])?],
    )?;
    let estimates = to_vec::<i32>(&est[0])?;
    let nonzero = estimates.iter().filter(|&&e| e > 0).count();
    println!("sketch: update+estimate round trip, {nonzero}/{b} keys counted");
    assert!(nonzero > b / 2, "sketch should count most updated keys");

    // --- the full cache simulator on a trace model.
    let sim = XlaSim::new(&rt, "cache_sim_k8")?;
    for trace_name in ["oltp", "wiki_a", "w3"] {
        let trace = paper::build(trace_name, 4 * sim.chunk, 7).unwrap();
        let t = Instant::now();
        let stats = sim.run(&trace)?;
        println!(
            "cache_sim[{trace_name}]: hit ratio {:.4} at {:.2} Mkeys/s",
            stats.hits as f64 / stats.accesses as f64,
            stats.accesses as f64 / t.elapsed().as_secs_f64() / 1e6
        );
    }
    println!("xla pipeline OK");
    Ok(())
}
