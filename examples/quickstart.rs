//! Quickstart: build a k-way cache, use it, and see the paper's point.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use kway::kway::{build, Variant};
use kway::policy::Policy;
use kway::sim;
use kway::trace::paper;

fn main() {
    // 1. A concurrent 8-way LRU cache with 2^11 entries (the paper's
    //    small-trace configuration) — wait-free separate-counters variant.
    let cache = build(Variant::Wfsc, 2048, 8, Policy::Lru);
    cache.put(1, 100);
    cache.put(2, 200);
    assert_eq!(cache.get(1), Some(100));
    assert_eq!(cache.get(3), None);
    println!("{}: len={} capacity={}", cache.name(), cache.len(), cache.capacity());

    // 2. Use it from many threads with zero synchronization setup —
    //    operations on different sets never contend (the paper's §1).
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let cache = &cache;
            s.spawn(move || {
                for i in 0..50_000u64 {
                    let key = t * 1_000_000 + i % 4096;
                    if cache.get(key).is_none() {
                        cache.put(key, key);
                    }
                }
            });
        }
    });
    println!("after 200k concurrent ops: len={} (≤ capacity)", cache.len());

    // 3. The headline hit-ratio claim: 8-way ≈ fully associative.
    let trace = paper::build("oltp", 300_000, 42).unwrap();
    let configs = [
        sim::Config::KWay { variant: Variant::Wfsc, ways: 8, policy: Policy::Lru, tlfu: false },
        sim::Config::FullLru { tlfu: false },
    ];
    println!("\nhit ratio on the OLTP model (capacity 2048):");
    for row in sim::sweep(&trace, 2048, &configs, 1) {
        println!("  {:12} {:.4}", row.label, row.hit_ratio);
    }
    println!("\n→ limited associativity costs almost nothing in hit ratio,");
    println!("  and each operation is a wait-free scan of one 8-way set.");
}
